"""L2 correctness: model shapes, determinism, numerics vs oracle."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def test_mlp_infer_shapes_and_probs():
    x = model.example_input(model.catalog((4,))[0])
    probs, preds = model.mlp_infer(x)
    assert probs.shape == (4, 10)
    assert preds.shape == (4,)
    np.testing.assert_allclose(np.sum(probs, axis=-1), np.ones(4), rtol=1e-5)
    assert np.all(np.asarray(preds) >= 0) and np.all(np.asarray(preds) < 10)


def test_mlp_infer_matches_ref_chain():
    params = model.mlp_params(model.MLP_INFER_DIMS)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 256), jnp.float32)
    probs, _ = model.mlp_infer(x, params)
    h = ref.mlp(x, params, ["gelu", "gelu", "none"])
    want = ref.row_softmax(h)
    np.testing.assert_allclose(probs, want, rtol=2e-4, atol=2e-5)


def test_text_featurize_shapes_and_range():
    toks = jax.random.randint(
        jax.random.PRNGKey(0), (4, model.TEXT_WINDOW), 0, model.TEXT_VOCAB
    )
    (feat,) = model.text_featurize(toks)
    assert feat.shape == (4, model.TEXT_OUT)
    # tanh output range
    assert np.all(np.abs(np.asarray(feat)) <= 1.0)


def test_text_featurize_out_of_vocab_tokens_zero_embed():
    # one_hot maps out-of-range ids to all-zero rows; must stay finite
    toks = jnp.full((2, model.TEXT_WINDOW), model.TEXT_VOCAB + 5, jnp.int32)
    (feat,) = model.text_featurize(toks)
    assert np.all(np.isfinite(np.asarray(feat)))


def test_anomaly_score_shapes_and_range():
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 128), jnp.float32)
    (score,) = model.anomaly_score(x)
    assert score.shape == (6,)
    s = np.asarray(score)
    assert np.all(s > 0.0) and np.all(s < 1.0)


def test_params_deterministic():
    a = model.mlp_params((32, 16, 8))
    b = model.mlp_params((32, 16, 8))
    for (wa, ba), (wb, bb) in zip(a, b):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(ba, bb)


def test_params_seed_sensitivity():
    a = model.mlp_params((32, 16), seed=1)
    b = model.mlp_params((32, 16), seed=2)
    assert not np.array_equal(np.asarray(a[0][0]), np.asarray(b[0][0]))


def test_catalog_covers_all_models_and_batches():
    cat = model.catalog((1, 4))
    names = {v.name for v in cat}
    assert names == {
        "mlp_infer_b1", "mlp_infer_b4",
        "text_featurize_b1", "text_featurize_b4",
        "anomaly_score_b1", "anomaly_score_b4",
    }
    for v in cat:
        assert v.flops > 0
        assert v.input_shape[0] == v.batch


def test_example_inputs_match_signature():
    for v in model.catalog((2,)):
        x = model.example_input(v)
        assert tuple(x.shape) == v.input_shape
        if v.input_dtype == "i32":
            assert x.dtype == jnp.int32
        else:
            assert x.dtype == jnp.float32
