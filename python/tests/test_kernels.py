"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

hypothesis sweeps shapes/dtypes/activations; assert_allclose against the
reference is the core correctness signal for the compiled artifacts.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, linear_block_shapes, ref, row_softmax
from compile.kernels.fused_linear import ACTIVATIONS
from compile.kernels import vmem


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


@pytest.mark.parametrize("activation", ACTIVATIONS)
def test_fused_linear_matches_ref_basic(activation):
    x = _rand(0, (8, 32), jnp.float32)
    w = _rand(1, (32, 16), jnp.float32)
    b = _rand(2, (16,), jnp.float32)
    got = fused_linear(x, w, b, activation=activation)
    want = ref.fused_linear(x, w, b, activation=activation)
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 96),
    n=st.integers(1, 80),
    act=st.sampled_from(ACTIVATIONS),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref_sweep(m, k, n, act, seed):
    x = _rand(seed, (m, k), jnp.float32)
    w = _rand(seed + 1, (k, n), jnp.float32)
    b = _rand(seed + 2, (n,), jnp.float32)
    got = fused_linear(x, w, b, activation=act)
    want = ref.fused_linear(x, w, b, activation=act)
    assert got.shape == (m, n)
    np.testing.assert_allclose(got, want, **TOL[jnp.float32])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_linear_dtypes(dtype):
    x = _rand(3, (16, 64), dtype)
    w = _rand(4, (64, 32), dtype)
    b = _rand(5, (32,), dtype)
    got = fused_linear(x, w, b, activation="gelu")
    want = ref.fused_linear(x, w, b, activation="gelu")
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype],
    )


def test_fused_linear_rejects_bad_activation():
    x = _rand(0, (4, 4), jnp.float32)
    with pytest.raises(ValueError):
        fused_linear(x, x, x[0], activation="swish")


def test_fused_linear_shape_mismatch_asserts():
    x = _rand(0, (4, 8), jnp.float32)
    w = _rand(1, (9, 4), jnp.float32)
    b = _rand(2, (4,), jnp.float32)
    with pytest.raises(AssertionError):
        fused_linear(x, w, b)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 512), k=st.integers(1, 1024), n=st.integers(1, 1024))
def test_block_shapes_divide_or_cover(m, k, n):
    bm, bn = linear_block_shapes(m, k, n)
    assert 1 <= bm <= m or bm == m
    assert 1 <= bn <= n or bn == n
    # blocks either divide the dim exactly or equal it (ragged fallback)
    assert m % bm == 0 or bm == m
    assert n % bn == 0 or bn == n


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), n=st.integers(1, 128), seed=st.integers(0, 1000))
def test_row_softmax_matches_ref(m, n, seed):
    x = _rand(seed, (m, n), jnp.float32) * 10.0
    got = row_softmax(x)
    want = ref.row_softmax(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.sum(got, axis=-1), np.ones(m), rtol=1e-5)


def test_row_softmax_extreme_values_stable():
    x = jnp.array([[1e4, -1e4, 0.0], [-1e4, -1e4, -1e4]], jnp.float32)
    got = np.asarray(row_softmax(x))
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got.sum(axis=-1), [1.0, 1.0], rtol=1e-5)


def test_vmem_estimates_fit_for_all_catalog_layers():
    # every layer of every served model must fit the 16 MiB VMEM budget
    from compile import model

    for dims in (model.MLP_INFER_DIMS, model.ANOMALY_DIMS,
                  (model.TEXT_EMBED, model.TEXT_OUT)):
        for k, n in zip(dims[:-1], dims[1:]):
            est = vmem.estimate_linear(16, k, n)
            assert est.fits_vmem, (k, n, est.vmem_bytes)
            assert 0.0 < est.mxu_utilization <= 1.0
