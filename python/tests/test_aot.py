"""AOT export tests: HLO text validity, manifest integrity, determinism."""

import json
import os

import numpy as np
import pytest
import jax

from compile import aot, model


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.export_all(str(out), batches=(1, 2))
    return str(out), manifest


def test_manifest_written_and_loadable(exported):
    out, manifest = exported
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(manifest))
    assert on_disk["interchange"] == "hlo-text"
    assert len(on_disk["entries"]) == 6


def test_hlo_text_is_parseable_hlo(exported):
    out, manifest = exported
    for e in manifest["entries"]:
        with open(os.path.join(out, e["file"])) as f:
            text = f.read()
        assert "ENTRY" in text and "ROOT" in text, e["name"]
        assert len(text) == e["hlo_bytes"]


def test_export_deterministic(exported, tmp_path):
    out, manifest = exported
    again = aot.export_all(str(tmp_path), batches=(1, 2))
    for a, b in zip(manifest["entries"], again["entries"]):
        assert a["hlo_sha256"] == b["hlo_sha256"], a["name"]


def test_lowered_module_executes_and_matches_eager(exported):
    # Compile the exported StableHLO back through jax and compare with the
    # eager model — guards against lowering-time constant corruption.
    v = model.catalog((2,))[0]  # mlp_infer_b2
    x = model.example_input(v)
    eager_probs, eager_pred = model.mlp_infer(x)
    lowered = jax.jit(v.fn).lower(x)
    compiled = lowered.compile()
    got = compiled(x)
    np.testing.assert_allclose(got[0], eager_probs, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(got[1], eager_pred)


def test_vmem_estimates_in_manifest(exported):
    _, manifest = exported
    for e in manifest["entries"]:
        assert e["vmem_fits"] is True
        assert 0.0 < e["mxu_utilization"] <= 1.0
