"""Pytest wiring for the Python (L1/L2 + AOT) layer.

The kernels/model/AOT tests need jax (and friends); CI environments that
only exercise the Rust control plane don't install it. Skip collection of
the affected files entirely in that case so `pytest python` stays green
instead of erroring at import time.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))


def _have(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ValueError):
        return False


collect_ignore_glob = []
if not (_have("jax") and _have("numpy")):
    # every test file imports jax/numpy at module scope
    collect_ignore_glob.append("tests/*")
elif not _have("hypothesis"):
    collect_ignore_glob.append("tests/test_kernels.py")
