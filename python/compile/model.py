"""Layer-2 JAX models: the serverless function bodies Archipelago serves.

Each model is one "function" in the paper's sense — the unit a sandbox
hosts and a worker core executes. They are small, latency-sensitive
inference graphs built from the Layer-1 Pallas kernels, with weights baked
in at lowering time (deterministic PRNG seed), so each HLO artifact is a
self-contained ``inputs -> outputs`` computation the Rust runtime can
execute with no parameter plumbing.

Catalog (names are what the manifest + Rust side use):

* ``mlp_infer``      — image-classify-style microservice: 256-d feature
                       vector -> 2 hidden GELU layers -> 10-way softmax.
                       The paper's C1/C3 "user-facing function" stand-in.
* ``text_featurize`` — embedding-bag + projection: mean-pooled one-hot
                       embedding of a token window -> 64-d feature. The
                       C2 "non-critical user-facing" stand-in.
* ``anomaly_score``  — background scorer: 128-d metric vector -> deep
                       narrow MLP -> scalar. The C4 "background job"
                       stand-in.

Each is exported at several batch sizes (the dynamic batcher on the Rust
side picks the variant that covers the batch).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import fused_linear, row_softmax

WEIGHT_SEED = 0x41C41  # deterministic across runs; tests rely on this


def _init_linear(key, fan_in: int, fan_out: int):
    wk, bk = jax.random.split(key)
    scale = (2.0 / (fan_in + fan_out)) ** 0.5
    w = jax.random.normal(wk, (fan_in, fan_out), jnp.float32) * scale
    b = jax.random.normal(bk, (fan_out,), jnp.float32) * 0.01
    return w, b


def mlp_params(layer_dims, seed: int = WEIGHT_SEED):
    """Deterministic params for a chain of linear layers."""
    key = jax.random.PRNGKey(seed)
    params = []
    for fan_in, fan_out in zip(layer_dims[:-1], layer_dims[1:]):
        key, sub = jax.random.split(key)
        params.append(_init_linear(sub, fan_in, fan_out))
    return params


# ---------------------------------------------------------------------------
# Function bodies
# ---------------------------------------------------------------------------

MLP_INFER_DIMS = (256, 512, 128, 10)


def mlp_infer(x, params=None):
    """User-facing classifier: ``[B, 256] -> ([B, 10] probs, [B] argmax)``."""
    if params is None:
        params = mlp_params(MLP_INFER_DIMS)
    (w0, b0), (w1, b1), (w2, b2) = params
    h = fused_linear(x, w0, b0, activation="gelu")
    h = fused_linear(h, w1, b1, activation="gelu")
    logits = fused_linear(h, w2, b2, activation="none")
    probs = row_softmax(logits)
    return probs, jnp.argmax(probs, axis=-1)


TEXT_VOCAB = 128
TEXT_WINDOW = 32
TEXT_EMBED = 96
TEXT_OUT = 64


def text_featurize(tokens, params=None):
    """Token window -> pooled feature: ``[B, 32] i32 -> [B, 64] f32``.

    The embedding lookup is expressed as one-hot @ table so the whole body
    stays on the fused_linear kernel path (gather-free; vocab is small).
    """
    if params is None:
        params = mlp_params((TEXT_EMBED, TEXT_OUT), seed=WEIGHT_SEED + 1)
    key = jax.random.PRNGKey(WEIGHT_SEED + 2)
    table = jax.random.normal(key, (TEXT_VOCAB, TEXT_EMBED), jnp.float32) * 0.1
    onehot = jax.nn.one_hot(tokens, TEXT_VOCAB, dtype=jnp.float32)  # [B,W,V]
    emb = jnp.einsum("bwv,ve->bwe", onehot, table)  # [B,W,E]
    pooled = jnp.mean(emb, axis=1)  # [B,E]
    (w, b) = params[0]
    return (fused_linear(pooled, w, b, activation="tanh"),)


ANOMALY_DIMS = (128, 256, 256, 64, 1)


def anomaly_score(x, params=None):
    """Background scorer: ``[B, 128] -> [B] score in (0, 1)``."""
    if params is None:
        params = mlp_params(ANOMALY_DIMS, seed=WEIGHT_SEED + 3)
    h = x
    for w, b in params[:-1]:
        h = fused_linear(h, w, b, activation="relu")
    w, b = params[-1]
    raw = fused_linear(h, w, b, activation="none")
    return (jax.nn.sigmoid(raw[:, 0]),)


# ---------------------------------------------------------------------------
# Export catalog
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Variant:
    """One exportable (function, batch) artifact."""

    model: str
    batch: int
    fn: object = field(compare=False)
    input_shape: tuple
    input_dtype: str
    output_shapes: tuple
    flops: int

    @property
    def name(self) -> str:
        return f"{self.model}_b{self.batch}"


def _mlp_flops(dims, batch):
    return sum(2 * batch * a * b for a, b in zip(dims[:-1], dims[1:]))


def catalog(batches=(1, 4, 16)) -> list[Variant]:
    """All exported variants, in manifest order."""
    out = []
    for b in batches:
        out.append(
            Variant(
                model="mlp_infer",
                batch=b,
                fn=lambda x: mlp_infer(x),
                input_shape=(b, MLP_INFER_DIMS[0]),
                input_dtype="f32",
                output_shapes=((b, MLP_INFER_DIMS[-1]), (b,)),
                flops=_mlp_flops(MLP_INFER_DIMS, b),
            )
        )
        out.append(
            Variant(
                model="text_featurize",
                batch=b,
                fn=lambda t: text_featurize(t),
                input_shape=(b, TEXT_WINDOW),
                input_dtype="i32",
                output_shapes=((b, TEXT_OUT),),
                flops=2 * b * TEXT_WINDOW * TEXT_VOCAB * TEXT_EMBED
                + _mlp_flops((TEXT_EMBED, TEXT_OUT), b),
            )
        )
        out.append(
            Variant(
                model="anomaly_score",
                batch=b,
                fn=lambda x: anomaly_score(x),
                input_shape=(b, ANOMALY_DIMS[0]),
                input_dtype="f32",
                output_shapes=((b,),),
                flops=_mlp_flops(ANOMALY_DIMS, b),
            )
        )
    return out


def example_input(variant: Variant):
    """Deterministic example input matching the variant's signature."""
    if variant.input_dtype == "i32":
        key = jax.random.PRNGKey(7)
        return jax.random.randint(key, variant.input_shape, 0, TEXT_VOCAB)
    key = jax.random.PRNGKey(7)
    return jax.random.normal(key, variant.input_shape, jnp.float32)
