"""AOT export: lower every L2 model variant to HLO text + manifest.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(what the Rust ``xla`` crate links) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never appears on the
request path. Output layout::

    artifacts/
      manifest.json            # catalog the Rust runtime loads
      <model>_b<batch>.hlo.txt # one self-contained module per variant

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import vmem


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant: model.Variant) -> str:
    spec = jax.ShapeDtypeStruct(
        variant.input_shape,
        {"f32": "float32", "i32": "int32"}[variant.input_dtype],
    )
    lowered = jax.jit(variant.fn).lower(spec)
    return to_hlo_text(lowered)


def export_all(out_dir: str, batches=(1, 4, 16)) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for variant in model.catalog(batches):
        text = lower_variant(variant)
        fname = f"{variant.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        # Structural perf estimate for the dominant layer (DESIGN.md §Perf).
        dims = {
            "mlp_infer": model.MLP_INFER_DIMS,
            "text_featurize": (model.TEXT_EMBED, model.TEXT_OUT),
            "anomaly_score": model.ANOMALY_DIMS,
        }[variant.model]
        k, n = max(zip(dims[:-1], dims[1:]), key=lambda kn: kn[0] * kn[1])
        est = vmem.estimate_linear(variant.batch, k, n)
        entries.append(
            {
                "name": variant.name,
                "model": variant.model,
                "batch": variant.batch,
                "file": fname,
                "input_shape": list(variant.input_shape),
                "input_dtype": variant.input_dtype,
                "output_shapes": [list(s) for s in variant.output_shapes],
                "flops": variant.flops,
                "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
                "hlo_bytes": len(text),
                "vmem_bytes": est.vmem_bytes,
                "vmem_fits": est.fits_vmem,
                "mxu_utilization": round(est.mxu_utilization, 4),
            }
        )
        print(f"  {variant.name}: {len(text)} chars, {variant.flops} flops")
    manifest = {
        "format": 1,
        "interchange": "hlo-text",
        "weight_seed": model.WEIGHT_SEED,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--batches",
        default="1,4,16",
        help="comma-separated batch sizes to export per model",
    )
    args = ap.parse_args()
    batches = tuple(int(b) for b in args.batches.split(","))
    manifest = export_all(args.out_dir, batches)
    print(
        f"wrote {len(manifest['entries'])} artifacts + manifest.json "
        f"to {args.out_dir}"
    )


if __name__ == "__main__":
    main()
