"""Layer-1 Pallas kernels for the served function bodies.

Archipelago's contribution is the serving control plane (Layer 3, Rust);
the data plane it schedules is real ML inference. These kernels implement
the compute hot-spots of those served functions and are lowered (inside the
Layer-2 JAX models) to HLO text consumed by the Rust PJRT runtime.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so TPU lowering is a compile-only target here. The
block shapes are still chosen for the TPU memory hierarchy (VMEM-resident
tiles feeding the MXU); see ``vmem.py`` for the footprint model used in
DESIGN.md §Perf.
"""

from .fused_linear import fused_linear, linear_block_shapes
from .softmax import row_softmax
from . import ref
from . import vmem

__all__ = [
    "fused_linear",
    "linear_block_shapes",
    "row_softmax",
    "ref",
    "vmem",
]
