"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written
with plain ``jax.numpy`` ops only — no Pallas, no custom lowering. pytest
(``python/tests/``) asserts ``assert_allclose(kernel, ref)`` across a
hypothesis-driven sweep of shapes/dtypes; this is the core L1 correctness
signal of the build.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_linear(x, w, b, *, activation: str = "none"):
    """Reference for ``kernels.fused_linear``: act(x @ w + b)."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    out = out + b.astype(jnp.float32)[None, :]
    if activation == "none":
        pass
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif activation == "tanh":
        out = jnp.tanh(out)
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(x.dtype)


def row_softmax(x):
    """Reference for ``kernels.row_softmax``."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def mlp(x, params, activations):
    """Reference MLP: chain of fused_linear layers."""
    h = x
    for (w, b), act in zip(params, activations):
        h = fused_linear(h, w, b, activation=act)
    return h
