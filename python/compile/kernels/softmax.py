"""Row-wise softmax Pallas kernel — the classifier-head epilogue.

One grid step owns a ``[bm, n]`` row block: the max-subtract, exp and
normalize all happen on the VPU while the block is VMEM-resident, so the
logits never round-trip to HBM between the three passes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANE = 8


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


@jax.jit
def row_softmax(x):
    """Numerically-stable softmax over the last axis of a 2-D array."""
    m, n = x.shape
    bm = m if m <= 256 else next(
        (d for d in range(256, _SUBLANE - 1, -_SUBLANE) if m % d == 0), m
    )
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        _softmax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
