"""VMEM footprint + MXU utilization model for the L1 kernels.

``interpret=True`` executes kernels as CPU numpy, so wallclock is not a TPU
proxy. Instead, per the build's hardware-adaptation rule, we *estimate* TPU
behaviour structurally from the BlockSpecs:

* VMEM footprint: bytes held live per grid step (input tiles + output tile
  + accumulator), doubled for the double-buffered pipeline Pallas emits.
* MXU utilization proxy: fraction of the 128x128 systolic array covered by
  the tile's (sublane, lane) footprint, times the K-depth amortization.

DESIGN.md §Perf reports these for every artifact variant.
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # v4/v5-class core budget
MXU_DIM = 128


@dataclass(frozen=True)
class LinearTileEstimate:
    """Static cost model for one fused_linear grid step."""

    bm: int
    bn: int
    k: int
    dtype_bytes: int

    @property
    def vmem_bytes(self) -> int:
        x_tile = self.bm * self.k * self.dtype_bytes
        w_tile = self.k * self.bn * self.dtype_bytes
        b_tile = self.bn * self.dtype_bytes
        out_tile = self.bm * self.bn * self.dtype_bytes
        acc = self.bm * self.bn * 4  # f32 accumulator
        # x2: Pallas double-buffers the HBM->VMEM streams.
        return 2 * (x_tile + w_tile + b_tile + out_tile) + acc

    @property
    def fits_vmem(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU lanes/sublanes covered by one pass."""
        sub = min(self.bm, MXU_DIM) / MXU_DIM
        lane = min(self.bn, MXU_DIM) / MXU_DIM
        depth = min(self.k, MXU_DIM) / MXU_DIM
        return sub * lane * min(1.0, depth)

    @property
    def flops(self) -> int:
        return 2 * self.bm * self.bn * self.k


def estimate_linear(m: int, k: int, n: int, dtype_bytes: int = 4):
    """Estimate for the block shapes ``linear_block_shapes`` would pick."""
    from .fused_linear import linear_block_shapes

    bm, bn = linear_block_shapes(m, k, n)
    return LinearTileEstimate(bm=bm, bn=bn, k=k, dtype_bytes=dtype_bytes)
