"""Fused linear layer Pallas kernel: ``out = act(x @ w + b)``.

This is the hot-spot of every served MLP function. Fusing the bias add and
activation into the matmul tile avoids a round-trip of the ``[bm, bn]``
output block through HBM per epilogue op — the same insight GPU serving
stacks apply with CUTLASS epilogues, re-thought for the TPU hierarchy:

* the grid iterates over ``(M/bm, N/bn)`` output tiles;
* each step holds an ``[bm, K]`` x-tile, ``[K, bn]`` w-tile and the
  ``[bm, bn]`` accumulator in VMEM (see ``vmem.py`` for the budget model);
* the contraction feeds the MXU via ``jnp.dot`` with an f32 accumulator
  (``preferred_element_type``), the bf16-in/f32-acc systolic-array idiom.

K is kept un-tiled: the served models have K <= 1024, so the x/w tiles fit
VMEM comfortably and a K-loop (with its accumulator carry) would only add
grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ACTIVATIONS = ("none", "relu", "gelu", "tanh")

# Hardware tile quanta: the MXU is 128x128 and the VPU lane width is 128,
# so block dims are chosen as multiples of 8 (sublane) x 128 (lane) when
# the problem is large enough, falling back to the full dim when small.
_LANE = 128
_SUBLANE = 8


def _apply_act(x, activation: str):
    if activation == "none":
        return x
    if activation == "relu":
        return jnp.maximum(x, 0.0)
    if activation == "gelu":
        # tanh-approximated GELU: cheap on the VPU, matches jax.nn.gelu's
        # approximate=True variant used by the reference oracle.
        return jax.nn.gelu(x, approximate=True)
    if activation == "tanh":
        return jnp.tanh(x)
    raise ValueError(f"unknown activation {activation!r}")


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    """One grid step: o[bm, bn] = act(x[bm, K] @ w[K, bn] + b[bn])."""
    acc = jnp.dot(
        x_ref[...],
        w_ref[...],
        preferred_element_type=jnp.float32,
    )
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = _apply_act(acc, activation).astype(o_ref.dtype)


def _block_dim(full: int, target: int, quantum: int) -> int:
    """Largest multiple of ``quantum`` <= target that divides ``full``.

    Falls back to ``full`` when the dim is smaller than one quantum or no
    divisor aligns — interpret mode tolerates ragged blocks, but aligned
    ones keep the TPU lowering honest.
    """
    if full <= target:
        return full
    best = None
    cap = min(target, full)
    d = (cap // quantum) * quantum
    while d >= quantum:
        if full % d == 0:
            best = d
            break
        d -= quantum
    return best if best is not None else full


def linear_block_shapes(m: int, k: int, n: int) -> tuple[int, int]:
    """Pick (bm, bn) output-tile dims for an ``[m,k] @ [k,n]`` problem.

    Sized so x-tile + w-tile + out-tile stay well under the ~16 MiB VMEM
    budget while keeping the MXU fed (>=128 lanes when available).
    """
    bm = _block_dim(m, 256, _SUBLANE)
    bn = _block_dim(n, 512, _LANE)
    return bm, bn


@functools.partial(jax.jit, static_argnames=("activation",))
def fused_linear(x, w, b, *, activation: str = "none"):
    """``act(x @ w + b)`` as a Pallas call.

    Args:
      x: ``[m, k]`` float array.
      w: ``[k, n]`` float array.
      b: ``[n]`` float array.
      activation: one of ``ACTIVATIONS``.

    Returns:
      ``[m, n]`` array with ``x.dtype``.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"
    if activation not in ACTIVATIONS:
        raise ValueError(f"unknown activation {activation!r}")

    bm, bn = linear_block_shapes(m, k, n)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn))

    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
