//! Open-loop overload tests (ISSUE 4 satellite): burst far above
//! cluster capacity through the non-blocking `submit_dag_async` path
//! and check the sink contract end-to-end — every submitted request
//! yields *exactly one* terminal result (met, missed, or failed), the
//! sink tallies reconcile with the shared `Metrics`, and the server
//! shuts down cleanly with requests still queued.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use archipelago::config::{SchedPolicy, MS};
use archipelago::dag::{DagId, DagSpec};
use archipelago::platform::realtime::{CompletionSink, RequestResult, RtOptions, Server};
use archipelago::runtime::{Manifest, StubExecutorFactory};

/// Counts every terminal result by kind and flags duplicate deliveries.
#[derive(Default)]
struct TallySink {
    met: AtomicU64,
    missed: AtomicU64,
    exec_failed: AtomicU64,
    shutdown_failed: AtomicU64,
    duplicates: AtomicU64,
    seen: Mutex<HashSet<u64>>,
}

impl TallySink {
    fn total(&self) -> u64 {
        self.met.load(Ordering::Relaxed)
            + self.missed.load(Ordering::Relaxed)
            + self.exec_failed.load(Ordering::Relaxed)
            + self.shutdown_failed.load(Ordering::Relaxed)
    }
}

impl CompletionSink for TallySink {
    fn complete(&self, r: RequestResult) {
        if !self.seen.lock().unwrap().insert(r.req().0) {
            self.duplicates.fetch_add(1, Ordering::Relaxed);
        }
        match r {
            RequestResult::Done(c) => {
                if c.deadline_met {
                    self.met.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.missed.fetch_add(1, Ordering::Relaxed);
                }
            }
            RequestResult::Failed(f) => {
                if f.error.contains("shut down") {
                    self.shutdown_failed.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.exec_failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

fn overload_server(exec_ms: u64) -> Server {
    // One shard, ONE worker core: any burst is instantly over capacity.
    let dags = vec![
        DagSpec::single(DagId(0), "work", 2 * MS, 10 * MS, 128, 10_000 * MS),
        DagSpec::single(DagId(1), "boom", 2 * MS, 10 * MS, 128, 10_000 * MS),
    ];
    let factory = Arc::new(StubExecutorFactory {
        exec_cost: Duration::from_millis(exec_ms),
        fail_artifacts: ["boom".to_string()].into_iter().collect(),
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs: 1,
        workers: 1,
        policy: SchedPolicy::Srsf,
        background_ticks: false,
        pool_mb: 4 * 1024,
    };
    Server::start_with(factory, dags, opts, &[], Manifest::empty()).unwrap()
}

fn wait_settled(sink: &TallySink, expected: u64) {
    let deadline = Instant::now() + Duration::from_secs(60);
    while sink.total() < expected && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn overload_burst_every_request_settles_and_reconciles_with_metrics() {
    let server = overload_server(2);
    let sink = Arc::new(TallySink::default());

    // 110 requests burst-submitted at a 1-core cluster (~220 ms of
    // work): 50 with a generous 10 s deadline (will be met), 50 with a
    // 1 ms deadline (cannot be met — execution alone takes 2 ms), and
    // 10 executor failures. Nothing blocks: the generator thread is
    // done submitting in microseconds per request.
    let mut submitted = 0u64;
    for i in 0..110u64 {
        let (dag, deadline) = match i % 11 {
            10 => (DagId(1), 10_000_000),      // boom
            x if x % 2 == 0 => (DagId(0), 10_000_000), // loose → met
            _ => (DagId(0), 1_000),            // tight → missed
        };
        let s: Arc<dyn CompletionSink> = sink.clone();
        assert!(
            server.submit_dag_async(dag, vec![1.0], deadline, s).is_some(),
            "known DAG must admit"
        );
        submitted += 1;
    }
    wait_settled(&sink, submitted);
    assert_eq!(sink.total(), submitted, "exactly one result per request");
    assert_eq!(sink.duplicates.load(Ordering::Relaxed), 0);
    assert_eq!(sink.exec_failed.load(Ordering::Relaxed), 10);
    assert_eq!(sink.shutdown_failed.load(Ordering::Relaxed), 0);
    assert_eq!(sink.met.load(Ordering::Relaxed), 50);
    assert_eq!(sink.missed.load(Ordering::Relaxed), 50);

    // Totals reconcile with the shared Metrics exactly: every request
    // completed its lifecycle; failures are counted and their timing
    // credit revoked.
    let row = server.summary();
    assert_eq!(row.completed, submitted);
    assert_eq!(row.failed, 10);
    assert!(
        (row.deadline_met_rate - 50.0 / 110.0).abs() < 1e-9,
        "metrics met-rate {} vs sink 50/110",
        row.deadline_met_rate
    );
    server.shutdown();
    assert_eq!(sink.total(), submitted, "shutdown adds nothing after settle");
}

#[test]
fn shutdown_with_queued_requests_fails_them_explicitly() {
    let server = overload_server(2);
    let sink = Arc::new(TallySink::default());

    // ~800 ms of queued work on one core; stop the server after ~100 ms.
    const BURST: u64 = 400;
    for _ in 0..BURST {
        let s: Arc<dyn CompletionSink> = sink.clone();
        assert!(server
            .submit_dag_async(DagId(0), vec![1.0], 60_000_000, s)
            .is_some());
    }
    std::thread::sleep(Duration::from_millis(100));
    let row = server.summary();
    server.shutdown(); // consumes the server; workers joined, pending drained

    assert_eq!(
        sink.total(),
        BURST,
        "every queued request must get a terminal result at shutdown"
    );
    assert_eq!(sink.duplicates.load(Ordering::Relaxed), 0);
    let done = sink.met.load(Ordering::Relaxed) + sink.missed.load(Ordering::Relaxed);
    let killed = sink.shutdown_failed.load(Ordering::Relaxed);
    assert!(done >= 1, "~100 ms of 2 ms jobs: some must have finished");
    assert!(
        killed > 0,
        "the burst cannot drain in 100 ms: requests must still be queued"
    );
    assert_eq!(done + killed, BURST);
    // The pre-shutdown metrics snapshot can only have counted requests
    // that completed their lifecycle — never the ones later killed.
    assert!(row.completed <= done, "snapshot {} vs done {done}", row.completed);
    assert_eq!(sink.exec_failed.load(Ordering::Relaxed), 0);
}
