//! End-to-end tests over the compiled artifacts: the PJRT runtime and
//! the real-time server. Skipped gracefully when `make artifacts` has
//! not been run (CI without Python).

use std::path::PathBuf;

use archipelago::config::SchedPolicy;
use archipelago::platform::realtime::Server;
use archipelago::runtime::{Input, Manifest, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn manifest_and_runtime_agree_on_shapes() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let manifest = Manifest::load(&dir).unwrap();
    let rt = Runtime::load_subset(&dir, &["mlp_infer_b1", "anomaly_score_b4"]).unwrap();
    for name in ["mlp_infer_b1", "anomaly_score_b4"] {
        let entry = manifest.entry(name).unwrap();
        let n: usize = entry.input_shape.iter().product();
        let input = vec![0.5f32; n];
        let out = rt.execute(name, Input::F32(&input)).unwrap();
        assert_eq!(out.len(), entry.output_shapes.len(), "{name}");
        for (tensor, shape) in out.iter().zip(&entry.output_shapes) {
            let expected: usize = shape.iter().product::<usize>().max(1);
            assert_eq!(tensor.len(), expected, "{name} output shape");
        }
    }
}

#[test]
fn realtime_server_mixed_load_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let server = Server::start(&dir, 2, SchedPolicy::Srsf, &["mlp_infer_b1"]).unwrap();
    // interleave three models; verify outputs numerically
    let mut receivers = Vec::new();
    for i in 0..30 {
        let rx = match i % 3 {
            0 => server.submit("mlp_infer_b1", vec![0.1; 256], 100_000),
            1 => server.submit("anomaly_score_b1", vec![0.2; 128], 400_000),
            _ => server.submit("mlp_infer_b4", vec![0.3; 4 * 256], 200_000),
        };
        receivers.push((i % 3, rx));
    }
    for (kind, rx) in receivers {
        let c = rx.recv().expect("completion");
        match kind {
            0 => {
                let probs = c.outputs[0].as_f32().unwrap();
                assert_eq!(probs.len(), 10);
                assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-3);
            }
            1 => {
                let score = c.outputs[0].as_f32().unwrap()[0];
                assert!(score > 0.0 && score < 1.0);
            }
            _ => {
                let probs = c.outputs[0].as_f32().unwrap();
                assert_eq!(probs.len(), 40);
            }
        }
        assert!(c.exec_us > 0);
    }
    // both workers ended up warm for the three models
    let warm = server.warm_counts();
    assert!(warm.iter().sum::<usize>() >= 3, "warm sets: {warm:?}");
    server.shutdown();
}

#[test]
fn fifo_policy_server_works_too() {
    let Some(dir) = artifacts_dir() else {
        return;
    };
    let server = Server::start(&dir, 1, SchedPolicy::Fifo, &["mlp_infer_b1"]).unwrap();
    let rx1 = server.submit("mlp_infer_b1", vec![0.7; 256], 50_000);
    let rx2 = server.submit("mlp_infer_b1", vec![0.9; 256], 10_000);
    // FIFO: first submitted completes first despite looser deadline
    let c1 = rx1.recv().unwrap();
    let c2 = rx2.recv().unwrap();
    assert!(c1.e2e_us <= c2.e2e_us + 500_000, "sanity");
    server.shutdown();
}
