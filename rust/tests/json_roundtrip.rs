//! Round-trip tests for the hand-rolled JSON layer on the two document
//! shapes the platform actually loads: platform config files and the DAG
//! upload language (the shapes `tests/integration.rs` drives end-to-end).
//! Each shape must survive parse → serialize → parse bit-exactly at the
//! `Json` value level, and malformed documents must be rejected, not
//! silently defaulted.

use archipelago::config::{Config, SchedPolicy};
use archipelago::dag::{parse_dag_json, DagId, DagSpec};
use archipelago::util::json;

const CONFIG_DOC: &str = r#"{
  "cluster": {"num_sgs": 4, "workers_per_sgs": 2, "cores_per_worker": 8,
              "worker_mem_mb": 16384, "proactive_pool_mb": 4096},
  "sgs": {"sched_policy": "fifo", "placement": "packed", "eviction": "lru",
          "estimate_interval_us": 50000, "sla_quantile": 0.95},
  "lbs": {"scale_out_threshold": 0.4, "ring_vnodes": 16,
          "scale_out_mode": "instant"}
}"#;

const DAG_DOC: &str = r#"{
  "name": "pipeline",
  "deadline_us": 400000,
  "functions": [
    {"name": "ingest", "exec_time_us": 30000, "setup_time_us": 150000,
     "mem_mb": 128, "artifact": "text_featurize_b1"},
    {"name": "score", "exec_time_us": 50000, "setup_time_us": 250000,
     "mem_mb": 256}
  ],
  "edges": [[0, 1]]
}"#;

/// parse → serialize → parse is the identity on the Json value.
#[test]
fn raw_json_value_roundtrips_on_both_shapes() {
    for doc in [CONFIG_DOC, DAG_DOC] {
        let v = json::parse(doc).unwrap();
        assert_eq!(json::parse(&v.to_string()).unwrap(), v, "compact");
        assert_eq!(json::parse(&v.to_pretty()).unwrap(), v, "pretty");
    }
}

/// Config: document → typed struct → document is stable, and the typed
/// fields survive the full cycle.
#[test]
fn config_roundtrips_through_typed_struct() {
    let cfg = Config::from_json_str(CONFIG_DOC).unwrap();
    assert_eq!(cfg.cluster.num_sgs, 4);
    assert_eq!(cfg.sgs.sched_policy, SchedPolicy::Fifo);
    assert_eq!(cfg.sgs.estimate_interval, 50_000);
    let emitted = cfg.to_json();
    let back = Config::from_json_str(&emitted.to_string()).unwrap();
    // re-serializing the re-parsed config is a fixed point
    assert_eq!(back.to_json(), emitted);
    assert_eq!(back.cluster.workers_per_sgs, cfg.cluster.workers_per_sgs);
    assert_eq!(back.lbs.ring_vnodes, cfg.lbs.ring_vnodes);
    assert_eq!(back.sgs.sla_quantile, cfg.sgs.sla_quantile);
}

/// DAG spec: upload document → DagSpec → document is stable, including
/// the optional artifact field and the edge list.
#[test]
fn dag_spec_roundtrips_through_typed_struct() {
    let dag = parse_dag_json(DagId(5), DAG_DOC).unwrap();
    assert_eq!(dag.functions[0].artifact, "text_featurize_b1");
    assert_eq!(dag.functions[1].mem_mb, 256);
    assert_eq!(dag.edges, vec![(0, 1)]);
    let emitted = dag.to_json();
    let back = parse_dag_json(DagId(5), &emitted.to_string()).unwrap();
    assert_eq!(back.to_json(), emitted);
    assert_eq!(back.total_cpl, dag.total_cpl);
    assert_eq!(back.deadline, dag.deadline);
    // programmatically built DAGs emit the same language
    let chain = DagSpec::chain(DagId(0), "c", &[(10, 20, 128), (30, 40, 64)], 100);
    let chain_back = parse_dag_json(DagId(0), &chain.to_json().to_pretty()).unwrap();
    assert_eq!(chain_back.to_json(), chain.to_json());
}

/// Malformed documents are rejected at the right layer with an error,
/// never silently coerced.
#[test]
fn malformed_documents_rejected() {
    // syntactically broken JSON fails the raw parser
    for bad in ["{", "{\"a\": }", "[1, 2,]", "{\"a\": 1} trailing", "\"\\u12\""] {
        assert!(json::parse(bad).is_err(), "{bad:?}");
    }
    // syntactically valid but shape-invalid config documents
    assert!(Config::from_json_str(r#"{"cluster": {"num_sgs": "four"}}"#).is_err());
    assert!(Config::from_json_str(r#"{"cluster": {"num_sgs": -1}}"#).is_err());
    assert!(Config::from_json_str(r#"{"sgs": {"sched_policy": "lifo"}}"#).is_err());
    assert!(Config::from_json_str(r#"{"cluster": {"num_sgs": 0}}"#).is_err());
    // shape-invalid DAG documents
    assert!(parse_dag_json(DagId(0), r#"{"deadline_us": 1}"#).is_err());
    assert!(parse_dag_json(
        DagId(0),
        r#"{"name": "x", "deadline_us": 1000, "functions": []}"#
    )
    .is_err());
    assert!(parse_dag_json(
        DagId(0),
        r#"{"name": "x", "deadline_us": 1000,
            "functions": [{"name": "f", "exec_time_us": 1,
                           "setup_time_us": 1, "mem_mb": 1}],
            "edges": [[0, 9]]}"#
    )
    .is_err());
}
