//! Golden pin for the simulator: `simulate`'s `SummaryRow` for a fixed
//! seed/config must not drift across refactors (the coordinator
//! extraction is behavior-preserving by construction; this test keeps it
//! that way).
//!
//! Snapshot protocol (bless-style):
//! * `rust/tests/golden/simulate_w2_seed42.json` present → the run must
//!   match it field-for-field.
//! * absent → the run records it and passes (first run on a fresh
//!   machine); commit the file to pin behavior.
//! * `ARCHIPELAGO_BLESS=1` → rewrite the snapshot after an intentional
//!   behavior change.

use std::path::PathBuf;

use archipelago::config::{Config, SEC};
use archipelago::metrics::SummaryRow;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::util::json::{self, Json};
use archipelago::workload::{macro_mix, WorkloadKind};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/simulate_w2_seed42.json")
}

fn fixed_run() -> (SummaryRow, u64) {
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 2;
    cfg.cluster.workers_per_sgs = 2;
    cfg.cluster.cores_per_worker = 4;
    cfg.cluster.proactive_pool_mb = 4 * 1024;
    let apps = macro_mix(WorkloadKind::W2, 1, 0.05, 42);
    let opts = SimOptions {
        seed: 42,
        horizon: 20 * SEC,
        warmup: 5 * SEC,
        ..SimOptions::default()
    };
    let mut p = SimPlatform::new(cfg, apps, opts);
    let row = p.run();
    (row, p.events_dispatched())
}

fn row_to_json(row: &SummaryRow, events: u64) -> String {
    json::obj(vec![
        ("completed", Json::Int(row.completed as i64)),
        ("p50_us", Json::Int(row.p50 as i64)),
        ("p90_us", Json::Int(row.p90 as i64)),
        ("p99_us", Json::Int(row.p99 as i64)),
        ("p999_us", Json::Int(row.p999 as i64)),
        ("max_us", Json::Int(row.max as i64)),
        ("deadline_met_rate", Json::Num(row.deadline_met_rate)),
        ("cold_starts", Json::Int(row.cold_starts as i64)),
        ("qdelay_p50_us", Json::Int(row.qdelay_p50 as i64)),
        ("qdelay_p99_us", Json::Int(row.qdelay_p99 as i64)),
        ("qdelay_p999_us", Json::Int(row.qdelay_p999 as i64)),
        ("events_dispatched", Json::Int(events as i64)),
    ])
    .to_pretty()
}

#[test]
fn simulate_summary_matches_golden_snapshot() {
    let (row, events) = fixed_run();
    let actual = row_to_json(&row, events);
    let path = golden_path();
    let bless = matches!(
        std::env::var("ARCHIPELAGO_BLESS"),
        Ok(v) if !v.is_empty() && v != "0"
    );
    match std::fs::read_to_string(&path) {
        Ok(expected) if !bless => {
            assert_eq!(
                actual.trim(),
                expected.trim(),
                "simulate SummaryRow drifted from the golden snapshot at {} — \
                 if the change is intentional, regenerate with ARCHIPELAGO_BLESS=1",
                path.display()
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &actual).unwrap();
            eprintln!("recorded golden snapshot at {}", path.display());
        }
    }
}

#[test]
fn simulate_is_bit_deterministic_across_runs() {
    // Full-field equality of two identical runs — a machine-independent
    // behavior pin that backs the snapshot above.
    let (a, ea) = fixed_run();
    let (b, eb) = fixed_run();
    assert_eq!(a, b, "identical seed/config must reproduce every field");
    assert_eq!(ea, eb, "event counts must match too");
    // sanity: the fixed workload actually exercises the system
    assert!(a.completed > 100, "completed {}", a.completed);
    assert!(a.cold_starts > 0 || a.deadline_met_rate > 0.5);
}
