//! Deterministic smoke tests for the sharded real-time platform: a
//! 3-function DAG served end-to-end through the shared coordinator with
//! the stub executor (no `xla` artifacts needed), asserting warm-vs-cold
//! accounting and deadline-ordered (SRSF) dispatch — plus a concurrency
//! smoke that drives multiple submitter threads across multiple SGS
//! shards (each behind its own lock).
//!
//! Determinism notes: dispatch decisions happen synchronously under the
//! home shard's lock at submit/complete time, so "worker busy → later
//! requests queue at the SGS" does not race with worker-thread wakeups,
//! and the stub's execution costs (tens of ms) dwarf scheduling
//! latencies (µs).

use std::collections::HashSet;
use std::sync::Arc;
use std::time::Duration;

use archipelago::config::{LbsConfig, SchedPolicy, MS};
use archipelago::dag::{DagId, DagSpec};
use archipelago::lbs::Lbs;
use archipelago::platform::realtime::{RtOptions, Server};
use archipelago::runtime::{Manifest, StubExecutorFactory};

fn chain3() -> DagSpec {
    DagSpec::chain(
        DagId(0),
        "pipeline",
        &[
            (10 * MS, 100 * MS, 128),
            (10 * MS, 100 * MS, 128),
            (10 * MS, 100 * MS, 128),
        ],
        2_000 * MS,
    )
}

fn start_stub(
    workers: usize,
    dags: Vec<DagSpec>,
    prewarm: &[&str],
    setup_ms: u64,
    exec_ms: u64,
) -> Server {
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::from_millis(setup_ms),
        exec_cost: Duration::from_millis(exec_ms),
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs: 1,
        workers,
        policy: SchedPolicy::Srsf,
        background_ticks: false,
        pool_mb: 4 * 1024,
    };
    Server::start_with(factory, dags, opts, prewarm, Manifest::empty()).unwrap()
}

#[test]
fn three_function_dag_cold_then_warm_accounting() {
    let server = start_stub(2, vec![chain3()], &[], 30, 15);
    let dag = server.dag_id("pipeline").unwrap();

    // First request: no sandbox exists anywhere — every stage is a cold
    // start and pays real (stub-compile) setup time.
    let c = server
        .submit_dag(dag, vec![2.0, 3.0], 2_000_000)
        .recv()
        .expect("first DAG completion");
    assert_eq!(c.functions.len(), 3, "all three stages executed");
    assert_eq!(c.cold_starts, 3, "first touch of each stage is cold");
    for f in &c.functions {
        assert!(f.cold, "stage {} should be cold", f.fn_idx);
        assert!(f.setup_us > 0, "cold stage must pay setup");
        assert_eq!(f.outputs[0].as_f32().unwrap(), &[5.0], "stub sums input");
    }
    // Stages of a chain run in dependency order.
    let order: Vec<u16> = c.functions.iter().map(|f| f.fn_idx).collect();
    assert_eq!(order, vec![0, 1, 2]);
    assert!(c.deadline_met, "2s deadline vs ~135ms E2E");

    // Second request (submitted after the first completed): warm-aware
    // placement routes every stage to the worker holding its sandbox.
    let c2 = server
        .submit_dag(dag, vec![1.0, 1.5], 2_000_000)
        .recv()
        .expect("second DAG completion");
    assert_eq!(c2.cold_starts, 0, "warm sandboxes must be reused");
    for f in &c2.functions {
        assert!(!f.cold, "stage {} should be warm", f.fn_idx);
        assert_eq!(f.setup_us, 0);
    }
    assert!(
        c2.e2e_us < c.e2e_us,
        "warm E2E ({}) must beat cold E2E ({})",
        c2.e2e_us,
        c.e2e_us
    );

    let row = server.summary();
    assert_eq!(row.completed, 2);
    assert_eq!(server.total_cold_starts(), 3);
    server.shutdown();
}

#[test]
fn srsf_dispatches_tighter_deadline_first() {
    // One worker, prewarmed: the first request occupies the only core;
    // the next two queue at the SGS and must leave in deadline order,
    // not arrival order.
    let dag = DagSpec::single(DagId(0), "job", 10 * MS, 100 * MS, 128, 5_000 * MS);
    let server = start_stub(1, vec![dag], &["job"], 0, 40);

    let rx_a = server.submit("job", vec![1.0], 5_000_000); // running
    let rx_b = server.submit("job", vec![2.0], 3_000_000); // queued 2nd…
    let rx_c = server.submit("job", vec![3.0], 1_000_000); // …but tighter

    let a = rx_a.recv().expect("a");
    let b = rx_b.recv().expect("b");
    let c = rx_c.recv().expect("c");
    assert!(!a.cold, "prewarmed");
    // C was submitted after B yet must complete before it: its E2E spans
    // one fewer 40 ms execution slot.
    assert!(
        c.e2e_us < b.e2e_us,
        "SRSF must run the tight deadline first: c={}us b={}us",
        c.e2e_us,
        b.e2e_us
    );

    let row = server.summary();
    assert_eq!(row.completed, 3);
    assert_eq!(row.deadline_met_rate, 1.0);
    server.shutdown();
}

#[test]
fn branched_dag_joins_and_aggregates() {
    use archipelago::dag::FunctionSpec;
    let functions = vec![
        FunctionSpec::new("split", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("left", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("right", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("join", 5 * MS, 100 * MS, 128),
    ];
    let dag = DagSpec::new(
        DagId(0),
        "diamond",
        functions,
        vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        2_000 * MS,
    )
    .unwrap();
    let server = start_stub(2, vec![dag], &[], 10, 10);
    let id = server.dag_id("diamond").unwrap();
    let c = server
        .submit_dag(id, vec![1.0], 2_000_000)
        .recv()
        .expect("diamond completion");
    assert_eq!(c.functions.len(), 4);
    // the join must be last; the split first
    assert_eq!(c.functions.first().unwrap().fn_idx, 0);
    assert_eq!(c.functions.last().unwrap().fn_idx, 3);
    assert!(c.deadline_met);
    server.shutdown();
}

#[test]
fn unregistered_dag_drops_channel_and_server_survives() {
    // Regression for the `Lbs::route` "route before register_dag" panic
    // path: a submit_dag with an id the server never saw must surface as
    // a closed reply channel, not a poisoned lock or a dead server.
    let server = start_stub(1, vec![chain3()], &[], 0, 5);
    let bogus = server.submit_dag(DagId(999), vec![1.0], 1_000_000);
    assert!(bogus.recv().is_err(), "unknown DAG must drop the channel");
    // the server still serves real traffic afterwards
    let dag = server.dag_id("pipeline").unwrap();
    let c = server
        .submit_dag(dag, vec![1.0, 2.0], 2_000_000)
        .recv()
        .expect("server must survive a bogus submit");
    assert_eq!(c.functions.len(), 3);
    let row = server.summary();
    assert_eq!(row.completed, 1, "only the real request counts");
    server.shutdown();
}

#[test]
fn concurrent_submitters_across_shards() {
    // The sharded-lock concurrency smoke (ISSUE 3 acceptance): ≥4
    // submitter threads drive DAGs spread across ≥2 SGS shards, each
    // shard behind its own lock. All deadlines and warm/cold accounting
    // must come out exact.
    const NUM_SGS: usize = 2;
    const WORKERS: usize = 2; // per shard
    const SUBMITTERS: u64 = 4;
    const PER_SUBMITTER: u64 = 24;
    const NUM_DAGS: u32 = 16;

    // The ring placement is deterministic (no per-seed salt): predict it
    // with a probe LBS so the cross-shard assertion below can't flake.
    let mut probe = Lbs::new(LbsConfig::default(), NUM_SGS, 0);
    let expected_shards: HashSet<u16> = (0..NUM_DAGS)
        .map(|i| probe.register_dag(DagId(i)).0)
        .collect();
    assert!(
        expected_shards.len() >= 2,
        "ring placement degenerate: all {NUM_DAGS} DAGs on one of {NUM_SGS} SGSs"
    );

    let dags: Vec<DagSpec> = (0..NUM_DAGS)
        .map(|i| {
            DagSpec::single(DagId(i), &format!("fn{i}"), 5 * MS, 100 * MS, 128, 10_000 * MS)
        })
        .collect();
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::from_millis(2),
        exec_cost: Duration::from_millis(2),
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs: NUM_SGS,
        workers: WORKERS,
        policy: SchedPolicy::Srsf,
        background_ticks: false,
        pool_mb: 4 * 1024,
    };
    let server =
        Server::start_with(factory, dags, opts, &[], Manifest::empty()).unwrap();

    let worker_threads: HashSet<usize> = std::thread::scope(|s| {
        let server = &server;
        let handles: Vec<_> = (0..SUBMITTERS)
            .map(|t| {
                s.spawn(move || {
                    let mut seen = HashSet::new();
                    for i in 0..PER_SUBMITTER {
                        let dag = DagId(((t * PER_SUBMITTER + i) % u64::from(NUM_DAGS)) as u32);
                        let c = server
                            .submit_dag(dag, vec![t as f32, i as f32], 10_000_000)
                            .recv()
                            .expect("completion under concurrency");
                        assert!(c.deadline_met, "10s deadline vs ms work");
                        assert_eq!(c.functions.len(), 1);
                        assert_eq!(
                            c.cold_starts,
                            u32::from(c.functions[0].cold),
                            "per-request cold accounting"
                        );
                        seen.insert(c.functions[0].worker);
                    }
                    seen
                })
            })
            .collect();
        let mut all = HashSet::new();
        for h in handles {
            all.extend(h.join().expect("submitter panicked"));
        }
        all
    });

    // Work executed on ≥2 shards (worker threads are shard-major:
    // thread t serves shard t / WORKERS).
    let used_shards: HashSet<usize> = worker_threads.iter().map(|t| t / WORKERS).collect();
    assert!(
        used_shards.len() >= 2,
        "expected ≥2 shards to execute work, got {used_shards:?} \
         (ring predicted {expected_shards:?})"
    );

    // Accounting integrity across shards.
    let total = SUBMITTERS * PER_SUBMITTER;
    let row = server.summary();
    assert_eq!(row.completed, total, "every request completed exactly once");
    assert_eq!(row.deadline_met_rate, 1.0);
    let colds = server.total_cold_starts();
    assert!(
        colds >= u64::from(NUM_DAGS),
        "each DAG's first touch is cold: {colds} < {NUM_DAGS}"
    );
    assert!(
        colds <= u64::from(NUM_DAGS) * WORKERS as u64,
        "cold starts bounded by workers per shard: {colds}"
    );

    // Warm-count integrity: with the system idle, a second sequential
    // pass must be served entirely from warm sandboxes.
    for i in 0..NUM_DAGS {
        let c = server
            .submit_dag(DagId(i), vec![1.0], 10_000_000)
            .recv()
            .expect("warm pass completion");
        assert!(!c.functions[0].cold, "dag {i} must hit its warm sandbox");
    }
    assert_eq!(server.total_cold_starts(), colds, "warm pass added no colds");
    server.shutdown();
}
