//! Deterministic smoke tests for the rebuilt real-time platform: a
//! 3-function DAG served end-to-end through the shared coordinator with
//! the stub executor (no `xla` artifacts needed), asserting warm-vs-cold
//! accounting and deadline-ordered (SRSF) dispatch.
//!
//! Determinism notes: dispatch decisions happen synchronously under the
//! server lock at submit/complete time, so "worker busy → later requests
//! queue at the SGS" does not race with worker-thread wakeups, and the
//! stub's execution costs (tens of ms) dwarf scheduling latencies (µs).

use std::sync::Arc;
use std::time::Duration;

use archipelago::config::{SchedPolicy, MS};
use archipelago::dag::{DagId, DagSpec};
use archipelago::platform::realtime::{RtOptions, Server};
use archipelago::runtime::{Manifest, StubExecutorFactory};

fn chain3() -> DagSpec {
    DagSpec::chain(
        DagId(0),
        "pipeline",
        &[
            (10 * MS, 100 * MS, 128),
            (10 * MS, 100 * MS, 128),
            (10 * MS, 100 * MS, 128),
        ],
        2_000 * MS,
    )
}

fn start_stub(
    workers: usize,
    dags: Vec<DagSpec>,
    prewarm: &[&str],
    setup_ms: u64,
    exec_ms: u64,
) -> Server {
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::from_millis(setup_ms),
        exec_cost: Duration::from_millis(exec_ms),
    });
    let opts = RtOptions {
        workers,
        policy: SchedPolicy::Srsf,
        background_ticks: false,
        pool_mb: 4 * 1024,
    };
    Server::start_with(factory, dags, opts, prewarm, Manifest::empty()).unwrap()
}

#[test]
fn three_function_dag_cold_then_warm_accounting() {
    let server = start_stub(2, vec![chain3()], &[], 30, 15);
    let dag = server.dag_id("pipeline").unwrap();

    // First request: no sandbox exists anywhere — every stage is a cold
    // start and pays real (stub-compile) setup time.
    let c = server
        .submit_dag(dag, vec![2.0, 3.0], 2_000_000)
        .recv()
        .expect("first DAG completion");
    assert_eq!(c.functions.len(), 3, "all three stages executed");
    assert_eq!(c.cold_starts, 3, "first touch of each stage is cold");
    for f in &c.functions {
        assert!(f.cold, "stage {} should be cold", f.fn_idx);
        assert!(f.setup_us > 0, "cold stage must pay setup");
        assert_eq!(f.outputs[0].as_f32().unwrap(), &[5.0], "stub sums input");
    }
    // Stages of a chain run in dependency order.
    let order: Vec<u16> = c.functions.iter().map(|f| f.fn_idx).collect();
    assert_eq!(order, vec![0, 1, 2]);
    assert!(c.deadline_met, "2s deadline vs ~135ms E2E");

    // Second request (submitted after the first completed): warm-aware
    // placement routes every stage to the worker holding its sandbox.
    let c2 = server
        .submit_dag(dag, vec![1.0, 1.5], 2_000_000)
        .recv()
        .expect("second DAG completion");
    assert_eq!(c2.cold_starts, 0, "warm sandboxes must be reused");
    for f in &c2.functions {
        assert!(!f.cold, "stage {} should be warm", f.fn_idx);
        assert_eq!(f.setup_us, 0);
    }
    assert!(
        c2.e2e_us < c.e2e_us,
        "warm E2E ({}) must beat cold E2E ({})",
        c2.e2e_us,
        c.e2e_us
    );

    let row = server.summary();
    assert_eq!(row.completed, 2);
    assert_eq!(server.total_cold_starts(), 3);
    server.shutdown();
}

#[test]
fn srsf_dispatches_tighter_deadline_first() {
    // One worker, prewarmed: the first request occupies the only core;
    // the next two queue at the SGS and must leave in deadline order,
    // not arrival order.
    let dag = DagSpec::single(DagId(0), "job", 10 * MS, 100 * MS, 128, 5_000 * MS);
    let server = start_stub(1, vec![dag], &["job"], 0, 40);

    let rx_a = server.submit("job", vec![1.0], 5_000_000); // running
    let rx_b = server.submit("job", vec![2.0], 3_000_000); // queued 2nd…
    let rx_c = server.submit("job", vec![3.0], 1_000_000); // …but tighter

    let a = rx_a.recv().expect("a");
    let b = rx_b.recv().expect("b");
    let c = rx_c.recv().expect("c");
    assert!(!a.cold, "prewarmed");
    // C was submitted after B yet must complete before it: its E2E spans
    // one fewer 40 ms execution slot.
    assert!(
        c.e2e_us < b.e2e_us,
        "SRSF must run the tight deadline first: c={}us b={}us",
        c.e2e_us,
        b.e2e_us
    );

    let row = server.summary();
    assert_eq!(row.completed, 3);
    assert_eq!(row.deadline_met_rate, 1.0);
    server.shutdown();
}

#[test]
fn branched_dag_joins_and_aggregates() {
    use archipelago::dag::FunctionSpec;
    let functions = vec![
        FunctionSpec::new("split", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("left", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("right", 5 * MS, 100 * MS, 128),
        FunctionSpec::new("join", 5 * MS, 100 * MS, 128),
    ];
    let dag = DagSpec::new(
        DagId(0),
        "diamond",
        functions,
        vec![(0, 1), (0, 2), (1, 3), (2, 3)],
        2_000 * MS,
    )
    .unwrap();
    let server = start_stub(2, vec![dag], &[], 10, 10);
    let id = server.dag_id("diamond").unwrap();
    let c = server
        .submit_dag(id, vec![1.0], 2_000_000)
        .recv()
        .expect("diamond completion");
    assert_eq!(c.functions.len(), 4);
    // the join must be last; the split first
    assert_eq!(c.functions.first().unwrap().fn_idx, 0);
    assert_eq!(c.functions.last().unwrap().fn_idx, 3);
    assert!(c.deadline_met);
    server.shutdown();
}
