//! Cross-module integration tests: config → DAG spec → platform →
//! metrics pipelines, baselines on shared workloads, state-store
//! round-trips, and the experiment registry in quick mode.

use archipelago::baseline::{BaselineKind, BaselineOptions, BaselineSim};
use archipelago::config::{Config, MS, SEC};
use archipelago::dag::{parse_dag_json, DagId};
use archipelago::experiments::{run_one, ExpContext};
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::state_store::StateStore;
use archipelago::util::json::{self, Json};
use archipelago::workload::{macro_mix, App, ArrivalProcess, DagClass, WorkloadKind};

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 2;
    cfg.cluster.workers_per_sgs = 2;
    cfg.cluster.cores_per_worker = 4;
    cfg.cluster.proactive_pool_mb = 4 * 1024;
    cfg
}

/// The full user journey: JSON config + JSON DAG upload → simulation →
/// metrics JSON.
#[test]
fn config_dag_platform_metrics_pipeline() {
    let cfg = Config::from_json_str(
        r#"{"cluster": {"num_sgs": 2, "workers_per_sgs": 2, "cores_per_worker": 4,
            "proactive_pool_mb": 4096, "worker_mem_mb": 8192}}"#,
    )
    .unwrap();
    let dag = parse_dag_json(
        DagId(0),
        r#"{"name": "api", "deadline_us": 300000,
            "functions": [
              {"name": "auth", "exec_time_us": 20000, "setup_time_us": 150000, "mem_mb": 128},
              {"name": "work", "exec_time_us": 60000, "setup_time_us": 200000, "mem_mb": 128}
            ],
            "edges": [[0, 1]]}"#,
    )
    .unwrap();
    let apps = vec![App {
        class: DagClass::C3,
        dag,
        arrivals: ArrivalProcess::constant(60.0),
    }];
    let opts = SimOptions {
        seed: 3,
        horizon: 15 * SEC,
        warmup: 2 * SEC,
        ..SimOptions::default()
    };
    let mut p = SimPlatform::new(cfg, apps, opts);
    let row = p.run();
    assert!(row.completed > 500, "completed {}", row.completed);
    assert!(row.deadline_met_rate > 0.95, "met {}", row.deadline_met_rate);
    // E2E must include both stages (80ms nominal, ±5% exec noise)
    assert!(row.p50 >= 75 * MS, "p50 {}", row.p50);
    // metrics serialize to valid JSON
    let j = p.metrics().to_json().to_string();
    let parsed = json::parse(&j).unwrap();
    assert_eq!(
        parsed.get("completed").unwrap().as_u64(),
        Some(row.completed)
    );
}

/// Archipelago beats the FIFO baseline on the same workload + hardware
/// when the sandbox pool is the binding constraint.
#[test]
fn archipelago_beats_baseline_under_churn() {
    // C1-style mix across 4 classes at moderate scale
    let apps = macro_mix(WorkloadKind::W2, 1, 0.05, 11);
    let cfg = small_cfg();
    let opts = SimOptions {
        seed: 11,
        horizon: 30 * SEC,
        warmup: 8 * SEC,
        ..SimOptions::default()
    };
    let mut arch = SimPlatform::new(cfg.clone(), apps.clone(), opts);
    let arch_row = arch.run();
    let bopts = BaselineOptions {
        kind: BaselineKind::CentralizedFifo,
        seed: 11,
        horizon: 30 * SEC,
        warmup: 8 * SEC,
        decision_cost: 100,
        ..BaselineOptions::default()
    };
    // baseline gets a realistic (small) warm-container pool
    let mut base = BaselineSim::new(4, 4, 1024, apps, bopts);
    let base_row = base.run();
    assert!(
        arch_row.deadline_met_rate >= base_row.deadline_met_rate,
        "arch {} < base {}",
        arch_row.deadline_met_rate,
        base_row.deadline_met_rate
    );
}

/// SGS/LBS state round-trips through the external store (§6.1).
#[test]
fn state_store_roundtrip_for_service_state() {
    let store = StateStore::new();
    // LBS state: per-DAG SGS mapping
    store.put(
        "lbs/dag/7/active",
        Json::Arr(vec![Json::Int(1), Json::Int(3)]),
    );
    // SGS state: estimates
    store.put(
        "sgs/3/estimates/dag7",
        json::obj(vec![("fn0", Json::Int(42)), ("fn1", Json::Int(17))]),
    );
    let snap = store.snapshot();
    let recovered = StateStore::restore(&snap).unwrap();
    assert_eq!(
        recovered.get("lbs/dag/7/active").unwrap().value,
        Json::Arr(vec![Json::Int(1), Json::Int(3)])
    );
    assert_eq!(
        recovered
            .get("sgs/3/estimates/dag7")
            .unwrap()
            .value
            .get("fn0")
            .unwrap()
            .as_i64(),
        Some(42)
    );
    assert_eq!(recovered.list("sgs/3/").len(), 1);
}

/// Every registered experiment runs end-to-end in quick mode and writes
/// its files. (The heavyweight macrobenchmarks are exercised separately
/// by `cargo bench`.)
#[test]
fn experiments_quick_mode_smoke() {
    let dir = std::env::temp_dir().join("archipelago_exp_smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let mut ctx = ExpContext::new(dir.to_str().unwrap());
    ctx.quick = true;
    for id in ["fig1", "fig2abc", "table1", "fig9", "fig12", "fig13"] {
        let res = run_one(id, &ctx).expect(id);
        assert!(!res.summary.is_empty(), "{id} summary empty");
        for f in &res.files {
            assert!(f.exists(), "{id} did not write {f:?}");
            let text = std::fs::read_to_string(f).unwrap();
            assert!(text.lines().count() > 1, "{id} wrote empty csv {f:?}");
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Determinism across the whole CLI-level pipeline.
#[test]
fn platform_determinism_across_workload_kinds() {
    for kind in [WorkloadKind::W1, WorkloadKind::W2] {
        let run = || {
            let apps = macro_mix(kind, 1, 0.02, 5);
            let opts = SimOptions {
                seed: 5,
                horizon: 10 * SEC,
                warmup: 2 * SEC,
                ..SimOptions::default()
            };
            let mut p = SimPlatform::new(small_cfg(), apps, opts);
            let row = p.run();
            (row.completed, row.p50, row.p99, row.cold_starts)
        };
        assert_eq!(run(), run(), "{kind:?} nondeterministic");
    }
}

/// Failure injection does not corrupt metrics or accounting even when
/// every SGS except one dies.
#[test]
fn cascading_sgs_failures_leave_one_survivor() {
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 4;
    cfg.cluster.workers_per_sgs = 2;
    cfg.cluster.cores_per_worker = 4;
    let apps = vec![App {
        class: DagClass::C1,
        dag: archipelago::dag::DagSpec::single(
            DagId(0),
            "survivor",
            30 * MS,
            150 * MS,
            128,
            300 * MS,
        ),
        arrivals: ArrivalProcess::constant(50.0),
    }];
    let opts = SimOptions {
        seed: 9,
        horizon: 20 * SEC,
        warmup: 2 * SEC,
        ..SimOptions::default()
    };
    let mut p = SimPlatform::new(cfg, apps, opts);
    use archipelago::sgs::SgsId;
    p.inject_sgs_failure(4 * SEC, SgsId(0));
    p.inject_sgs_failure(6 * SEC, SgsId(1));
    p.inject_sgs_failure(8 * SEC, SgsId(2));
    let row = p.run();
    p.check_invariants().unwrap();
    assert!(row.completed > 250, "completed {}", row.completed);
    let active = p.lbs().active_sgs(DagId(0));
    assert!(
        active.iter().all(|s| s.0 == 3),
        "only SGS 3 survives: {active:?}"
    );
}
