//! Property-based tests over coordinator invariants, driven by the
//! in-tree mini property-testing framework (`util::prop`; proptest is
//! unavailable offline — see DESIGN.md §4).
//!
//! Each property generates randomized operation sequences or platform
//! workloads and asserts structural invariants: conservation of
//! sandbox-memory accounting, scheduler ordering, routing validity, and
//! whole-platform bookkeeping after arbitrary fault injections.

use archipelago::config::{
    Config, EvictionPolicy, PlacementPolicy, SchedPolicy, MS, SEC,
};
use archipelago::dag::{DagId, DagSpec, FnId};
use archipelago::lbs::HashRing;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::sandbox::SandboxTable;
use archipelago::sgs::scheduler::{QueuedFn, RequestId, SchedQueue};
use archipelago::sgs::SgsId;
use archipelago::util::prop::{check, Gen};
use archipelago::util::rng::{poisson_inv_cdf, Rng};
use archipelago::worker::WorkerId;
use archipelago::workload::{App, ArrivalProcess, DagClass};

fn fid(i: u16) -> FnId {
    FnId {
        dag: DagId(0),
        idx: i,
    }
}

/// Sandbox-table accounting survives arbitrary valid operation sequences.
#[test]
fn prop_sandbox_table_memory_conservation() {
    check("sandbox memory conservation", 200, |g: &mut Gen| {
        let pool = 128 * g.u64(4, 64);
        let mut t = SandboxTable::new(pool);
        let nfns = g.usize(1, 6) as u16;
        for _ in 0..g.usize(10, 120) {
            let f = fid(g.u64(0, nfns as u64) as u16);
            match g.u64(0, 7) {
                0 => {
                    let _ = t.begin_setup(f, 128);
                }
                1 => {
                    let _ = t.finish_setup(f);
                }
                2 => {
                    let _ = t.acquire_warm(f, g.u64(0, 1000));
                }
                3 => {
                    let _ = t.acquire_cold(f, 128, g.u64(0, 1000));
                }
                4 => {
                    let _ = t.release(f, g.u64(0, 1000));
                }
                5 => {
                    let _ = t.soft_evict_one(f);
                }
                6 => {
                    let _ = t.soft_revive_one(f);
                }
                _ => {
                    let _ = t.hard_evict_one(f);
                }
            }
            t.check_invariants()?;
            if t.pool_used_mb() > pool {
                return Err(format!("pool overcommit: {} > {pool}", t.pool_used_mb()));
            }
        }
        Ok(())
    });
}

/// SRSF pop order is always non-decreasing in the static slack key, and
/// every pushed element is popped exactly once.
#[test]
fn prop_srsf_queue_ordering_and_conservation() {
    check("srsf ordering + conservation", 200, |g: &mut Gen| {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        let n = g.usize(1, 120);
        for i in 0..n {
            q.push(QueuedFn {
                req: RequestId(i as u64),
                f: fid(0),
                dag: DagId(0),
                enqueued_at: 0,
                deadline_abs: g.u64(0, 1_000_000),
                remaining_work: g.u64(1, 500_000),
                exec_time: 1000,
                setup_time: 1000,
                mem_mb: 128,
            });
        }
        let mut seen = vec![false; n];
        let mut last_key = i64::MIN;
        while let Some(item) = q.pop() {
            let key = item.srsf_key();
            if key < last_key {
                return Err(format!("key went backwards: {key} < {last_key}"));
            }
            last_key = key;
            let idx = item.req.0 as usize;
            if seen[idx] {
                return Err(format!("request {idx} popped twice"));
            }
            seen[idx] = true;
        }
        if !seen.iter().all(|s| *s) {
            return Err("some requests never popped".into());
        }
        Ok(())
    });
}

/// pop_feasible never loses requests regardless of the predicate.
#[test]
fn prop_pop_feasible_conserves_queue() {
    check("pop_feasible conservation", 150, |g: &mut Gen| {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        let n = g.usize(1, 60);
        for i in 0..n {
            q.push(QueuedFn {
                req: RequestId(i as u64),
                f: fid(0),
                dag: DagId(0),
                enqueued_at: 0,
                deadline_abs: g.u64(0, 100_000),
                remaining_work: g.u64(1, 50_000),
                exec_time: 10,
                setup_time: 10,
                mem_mb: 128,
            });
        }
        let m = g.u64(1, 5);
        let popped = q.pop_feasible(g.usize(1, 32), |c| c.req.0 % m == 0);
        let total = q.len() + usize::from(popped.is_some());
        if total != n {
            return Err(format!("lost requests: {total} != {n}"));
        }
        Ok(())
    });
}

/// SRSF audit (§4.2): at any observation time, every request whose
/// remaining slack has gone negative pops before any request whose slack
/// is still positive — urgency is never starved by arrival order.
#[test]
fn prop_srsf_negative_slack_outranks_positive() {
    check("srsf negative slack priority", 200, |g: &mut Gen| {
        let now = g.u64(100_000, 1_000_000);
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        let n = g.usize(2, 100);
        for i in 0..n {
            q.push(QueuedFn {
                req: RequestId(i as u64),
                f: fid(0),
                dag: DagId(0),
                enqueued_at: 0,
                deadline_abs: g.u64(0, 2 * now),
                remaining_work: g.u64(1, now),
                exec_time: 1000,
                setup_time: 1000,
                mem_mb: 128,
            });
        }
        let mut seen_positive = false;
        while let Some(item) = q.pop() {
            let slack = item.remaining_slack(now);
            if slack >= 0 {
                seen_positive = true;
            } else if seen_positive {
                return Err(format!(
                    "negative-slack request {} (slack {slack}) popped after a \
                     positive-slack one",
                    item.req.0
                ));
            }
        }
        Ok(())
    });
}

/// SRSF tie-break audit: among requests with an identical static SRSF key
/// (`deadline_abs − remaining_work`), pop order is least remaining work
/// first, and FIFO (push sequence) within equal work.
#[test]
fn prop_srsf_ties_break_by_work_then_fifo() {
    check("srsf tie-break work-then-fifo", 200, |g: &mut Gen| {
        let key = g.u64(1_000, 1_000_000);
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        let n = g.usize(2, 60);
        for i in 0..n {
            let work = g.u64(1, 8); // small range forces work ties too
            q.push(QueuedFn {
                req: RequestId(i as u64), // == push sequence
                f: fid(0),
                dag: DagId(0),
                enqueued_at: 0,
                // deadline_abs − remaining_work == key for every request
                deadline_abs: key + work,
                remaining_work: work,
                exec_time: 1000,
                setup_time: 1000,
                mem_mb: 128,
            });
        }
        let mut last: Option<(u64, u64)> = None;
        while let Some(item) = q.pop() {
            if item.srsf_key() != key as i64 {
                return Err(format!("key drifted: {}", item.srsf_key()));
            }
            let cur = (item.remaining_work, item.req.0);
            if let Some(prev) = last {
                if cur < prev {
                    return Err(format!(
                        "tie-break violated: popped (work, seq) {cur:?} after {prev:?}"
                    ));
                }
            }
            last = Some(cur);
        }
        Ok(())
    });
}

/// The hash ring's successor walk visits every SGS exactly once for any
/// DAG key, and the primary is stable.
#[test]
fn prop_hash_ring_walk_is_permutation() {
    check("ring walk permutation", 100, |g: &mut Gen| {
        let sgs_count = g.usize(1, 16);
        let vnodes = g.usize(1, 64);
        let ring = HashRing::new(sgs_count, vnodes);
        let key = g.u64(0, u64::MAX - 1);
        let walk: Vec<SgsId> = ring.successors(key).collect();
        if walk.len() != sgs_count {
            return Err(format!("walk length {} != {sgs_count}", walk.len()));
        }
        let mut ids: Vec<u16> = walk.iter().map(|s| s.0).collect();
        ids.sort_unstable();
        ids.dedup();
        if ids.len() != sgs_count {
            return Err("walk revisited an SGS".into());
        }
        if ring.primary(key) != walk[0] {
            return Err("primary != first successor".into());
        }
        Ok(())
    });
}

/// Poisson inverse CDF is monotone in both quantile and rate.
#[test]
fn prop_poisson_inv_cdf_monotone() {
    check("poisson inv cdf monotonicity", 150, |g: &mut Gen| {
        let lambda = g.f64(0.01, 500.0);
        let q1 = g.f64(0.5, 0.99);
        let q2 = (q1 + g.f64(0.0, 0.009)).min(0.9999);
        let k1 = poisson_inv_cdf(q1, lambda);
        let k2 = poisson_inv_cdf(q2, lambda);
        if k2 < k1 {
            return Err(format!("not monotone in q: {k1} vs {k2}"));
        }
        let k3 = poisson_inv_cdf(q1, lambda * 1.5);
        if k3 < k1 {
            return Err(format!("not monotone in lambda: {k1} vs {k3}"));
        }
        Ok(())
    });
}

/// Whole-platform invariant fuzz: random small clusters, random apps,
/// random fault injections — after the run, core/memory accounting is
/// intact and sane.
#[test]
fn prop_platform_survives_random_scenarios() {
    check("platform fuzz", 12, |g: &mut Gen| {
        let mut cfg = Config::default();
        cfg.cluster.num_sgs = g.usize(1, 4);
        cfg.cluster.workers_per_sgs = g.usize(1, 4);
        cfg.cluster.cores_per_worker = g.u64(1, 6) as u32;
        cfg.cluster.proactive_pool_mb = 128 * g.u64(2, 40);
        cfg.cluster.worker_mem_mb = cfg.cluster.proactive_pool_mb;
        cfg.sgs.placement = *g.choose(&[PlacementPolicy::Even, PlacementPolicy::Packed]);
        cfg.sgs.eviction = *g.choose(&[EvictionPolicy::Fair, EvictionPolicy::Lru]);
        let n_apps = g.usize(1, 4);
        let mut apps = Vec::new();
        for i in 0..n_apps {
            let exec = g.u64(5, 120) * MS;
            let setup = g.u64(125, 400) * MS;
            let deadline = exec + g.u64(50, 800) * MS;
            let rate = g.f64(5.0, 120.0);
            let arrivals = if g.bool() {
                ArrivalProcess::constant(rate)
            } else {
                ArrivalProcess::sinusoid(rate, rate * g.f64(0.1, 0.9), g.u64(4, 20) * SEC)
            };
            apps.push(App {
                class: DagClass::C1,
                dag: if g.bool() {
                    DagSpec::single(DagId(0), &format!("p{i}"), exec, setup, 128, deadline)
                } else {
                    DagSpec::chain(
                        DagId(0),
                        &format!("p{i}"),
                        &[(exec / 2, setup, 128), (exec / 2, setup, 128)],
                        deadline,
                    )
                },
                arrivals,
            });
        }
        let opts = SimOptions {
            seed: g.u64(0, u64::MAX - 1),
            horizon: g.u64(5, 15) * SEC,
            warmup: SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg.clone(), apps, opts);
        for _ in 0..g.usize(0, 3) {
            let at = g.u64(1, 10) * SEC;
            let sgs = SgsId(g.u64(0, cfg.cluster.num_sgs as u64) as u16);
            if g.bool() {
                let w = WorkerId(g.u64(0, cfg.cluster.workers_per_sgs as u64) as u16);
                p.inject_worker_failure(at, sgs, w);
                if g.bool() {
                    p.inject_worker_recovery(at + 2 * SEC, sgs, w);
                }
            } else if cfg.cluster.num_sgs > 1 {
                p.inject_sgs_failure(at, sgs);
            }
        }
        let row = p.run();
        p.check_invariants()?;
        if row.completed > 0 && row.p50 == 0 {
            return Err("completed requests with zero latency".into());
        }
        Ok(())
    });
}

/// Determinism: identical (config, apps, seed) ⇒ identical results.
#[test]
fn prop_platform_deterministic() {
    check("platform determinism", 6, |g: &mut Gen| {
        let seed = g.u64(0, u64::MAX - 1);
        let rate = g.f64(20.0, 150.0);
        let run = || {
            let mut cfg = Config::default();
            cfg.cluster.num_sgs = 2;
            cfg.cluster.workers_per_sgs = 2;
            cfg.cluster.cores_per_worker = 4;
            let apps = vec![App {
                class: DagClass::C1,
                dag: DagSpec::single(DagId(0), "d", 40 * MS, 200 * MS, 128, 200 * MS),
                arrivals: ArrivalProcess::constant(rate),
            }];
            let opts = SimOptions {
                seed,
                horizon: 8 * SEC,
                warmup: SEC,
                ..SimOptions::default()
            };
            let mut p = SimPlatform::new(cfg, apps, opts);
            let row = p.run();
            (
                row.completed,
                row.p50,
                row.p99,
                row.p999,
                row.cold_starts,
                p.events_dispatched(),
            )
        };
        if run() != run() {
            return Err("nondeterministic run".into());
        }
        Ok(())
    });
}

/// RNG distribution sanity under random parameters.
#[test]
fn prop_rng_distributions_parametric() {
    check("rng distributions", 60, |g: &mut Gen| {
        let mut rng = Rng::new(g.u64(0, u64::MAX - 1));
        let lambda = g.f64(0.1, 50.0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exp(lambda)).sum::<f64>() / n as f64;
        let expected = 1.0 / lambda;
        if (mean - expected).abs() > expected * 0.1 {
            return Err(format!("exp mean {mean} vs {expected}"));
        }
        let lo = g.u64(0, 1000);
        let hi = lo + g.u64(1, 1000);
        for _ in 0..1000 {
            let v = rng.range_u64(lo, hi);
            if v < lo || v >= hi {
                return Err(format!("uniform out of range: {v} not in [{lo},{hi})"));
            }
        }
        Ok(())
    });
}
