//! §7.4 system-overhead microbenchmarks, real code on the hot paths:
//!
//! | paper (Go prototype)        | median | p99    |
//! |-----------------------------|--------|--------|
//! | LBS routing decision        | 190 µs | 212 µs |
//! | SGS scheduling decision     | 241 µs | 342 µs |
//! | LBS scale-out decision      | 128 µs | 197 µs |
//! | SGS estimation pass         | 879 µs | 1352 µs|
//!
//! Run with `cargo bench`; output feeds EXPERIMENTS.md §7.4.

use archipelago::config::{Config, LbsConfig, SchedPolicy, MS};
use archipelago::dag::{DagId, DagRegistry, DagSpec, FnId};
use archipelago::lbs::{Lbs, SgsReport};
use archipelago::sgs::scheduler::{QueuedFn, RequestId, SchedQueue};
use archipelago::sgs::{Sgs, SgsId};
use archipelago::util::bench::Bench;
use archipelago::util::rng::Rng;

fn queued(i: u64, rng: &mut Rng) -> QueuedFn {
    QueuedFn {
        req: RequestId(i),
        f: FnId {
            dag: DagId((i % 16) as u32),
            idx: 0,
        },
        dag: DagId((i % 16) as u32),
        enqueued_at: 0,
        deadline_abs: rng.range_u64(100_000, 2_000_000),
        remaining_work: rng.range_u64(10_000, 500_000),
        exec_time: 50_000,
        setup_time: 200_000,
        mem_mb: 128,
    }
}

fn main() {
    let bench = Bench::default();
    println!("== §7.4 control-plane overheads (paper medians in header) ==");

    // --- LBS routing decision (paper: 190 µs median) ---
    let mut lbs = Lbs::new(LbsConfig::default(), 8, 1);
    for d in 0..16u32 {
        lbs.register_dag(DagId(d));
        // grown association set + reports, the realistic steady state
        for s in 0..4u16 {
            lbs.update_report(
                DagId(d),
                SgsReport {
                    sgs: SgsId(s),
                    sandboxes: 20 + u32::from(s),
                    qdelay_us: 500.0,
                    window_full: true,
                },
            );
        }
    }
    let mut d = 0u32;
    let mut r = bench.run("lbs_route (paper 190µs / 212µs p99)", || {
        d = (d + 1) % 16;
        lbs.route(DagId(d))
    });
    println!("{}", r.report_line());

    // --- SGS scheduling decision (paper: 241 µs median) ---
    // steady-state queue of 256 requests: one push + one pop per decision
    let mut queue = SchedQueue::new(SchedPolicy::Srsf);
    let mut rng = Rng::new(7);
    for i in 0..256 {
        queue.push(queued(i, &mut rng));
    }
    let mut i = 256;
    let mut r = bench.run("sgs_schedule_decision (paper 241µs / 342µs p99)", || {
        i += 1;
        queue.push(queued(i, &mut rng));
        queue.pop_feasible(16, |_| true)
    });
    println!("{}", r.report_line());

    // --- LBS scale-out decision (paper: 128 µs median) ---
    let mut r = bench.run("lbs_scale_decision (paper 128µs / 197µs p99)", || {
        lbs.control_tick(DagId(3), 150 * MS)
    });
    println!("{}", r.report_line());

    // --- SGS estimation pass (paper: 879 µs median) ---
    // 16 DAGs tracked, arrivals recorded, full demand + reconcile pass
    let mut registry = DagRegistry::new();
    for d in 0..16u32 {
        registry.register(DagSpec::single(
            DagId(d),
            &format!("d{d}"),
            50 * MS,
            200 * MS,
            128,
            200 * MS,
        ));
    }
    let mut sgs = Sgs::new(SgsId(0), 8, 20, 32 * 1024, Config::default().sgs);
    let mut now = 0;
    let mut r = bench.run("sgs_estimation_pass (paper 879µs / 1352µs p99)", || {
        for d in 0..16u32 {
            for _ in 0..8 {
                sgs.estimator.record_arrival(DagId(d));
            }
        }
        now += 100_000;
        sgs.estimator_tick(now, &registry)
    });
    println!("{}", r.report_line());

    println!("\nnote: in-process Rust vs the paper's multi-process Go + protobuf RPC —");
    println!("all four decisions must land well under the paper's budgets.");
}
