//! Scheduling-plane scale bench (ISSUE 3): sustained `submit_dag`
//! throughput against the stub executor at 1/2/4/8 submitter threads,
//! comparing the **global-lock baseline** (one coordinator shard — all
//! submitters, workers, and completions serialize on a single mutex,
//! exactly the pre-sharding architecture) against the **sharded**
//! configuration (4 shards, one lock each) *in the same run*, with the
//! same total worker count. Writes `BENCH_scale.json` so perf PRs have
//! an in-repo anchor for the multi-core scheduling win.
//!
//! The stub executor costs ~zero, so throughput is bounded by the
//! scheduling plane itself: admission routing, SRSF push/pop, dispatch,
//! and completion bookkeeping — the paths the per-shard locks decouple.

use std::sync::Arc;
use std::time::{Duration, Instant};

use archipelago::config::{SchedPolicy, MS};
use archipelago::dag::{DagId, DagSpec};
use archipelago::platform::realtime::{RtOptions, Server};
use archipelago::runtime::{Manifest, StubExecutorFactory};
use archipelago::util::json::{self, Json};

/// DAG population: enough distinct DAGs that the ring spreads them over
/// every shard in the sharded configuration.
const NUM_DAGS: u32 = 16;
/// In-flight window per submitter (pipelining keeps the scheduling
/// plane saturated instead of measuring reply-channel round-trips).
const WINDOW: usize = 16;
/// Requests per submitter thread per configuration.
const PER_SUBMITTER: usize = 1_600;
/// Total worker threads in every configuration (fair capacity).
const TOTAL_WORKERS: usize = 8;

fn start_server(num_sgs: usize) -> Server {
    let dags: Vec<DagSpec> = (0..NUM_DAGS)
        .map(|i| DagSpec::single(DagId(i), &format!("fn{i}"), MS, 10 * MS, 128, 10_000 * MS))
        .collect();
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::ZERO,
        exec_cost: Duration::ZERO,
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs,
        workers: TOTAL_WORKERS / num_sgs,
        policy: SchedPolicy::Srsf,
        background_ticks: false,
        pool_mb: 4 * 1024,
    };
    Server::start_with(factory, dags, opts, &[], Manifest::empty()).expect("server start")
}

/// Sustained submit_dag throughput (requests/sec) for one configuration.
fn throughput(num_sgs: usize, submitters: usize) -> f64 {
    let server = start_server(num_sgs);
    // Touch every DAG once so the measured phase is steady-state (no
    // cold-start compiles on the clock).
    for i in 0..NUM_DAGS {
        server
            .submit_dag(DagId(i), vec![1.0], 10_000_000)
            .recv()
            .expect("warmup completion");
    }
    let total = submitters * PER_SUBMITTER;
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for t in 0..submitters {
            let server = &server;
            s.spawn(move || {
                let mut rxs = Vec::with_capacity(WINDOW);
                let mut sent = 0usize;
                while sent < PER_SUBMITTER {
                    let burst = WINDOW.min(PER_SUBMITTER - sent);
                    for i in 0..burst {
                        let n = t * PER_SUBMITTER + sent + i;
                        let dag = DagId((n % NUM_DAGS as usize) as u32);
                        rxs.push(server.submit_dag(dag, vec![t as f32], 10_000_000));
                    }
                    sent += burst;
                    for rx in rxs.drain(..) {
                        let c = rx.recv().expect("completion");
                        assert!(c.deadline_met, "10s deadline vs ~zero-cost work");
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let row = server.summary();
    assert_eq!(row.completed, (total + NUM_DAGS as usize) as u64);
    server.shutdown();
    total as f64 / wall
}

fn main() {
    println!("== scheduling-plane scale bench ==");
    println!(
        "{TOTAL_WORKERS} worker threads total; baseline = 1 shard (global lock), \
         sharded = 4 shards (one lock each); {NUM_DAGS} DAGs, window {WINDOW}"
    );
    let mut rows = Vec::new();
    let mut speedup_at_4 = 0.0;
    for &threads in &[1usize, 2, 4, 8] {
        let baseline = throughput(1, threads);
        let sharded = throughput(4, threads);
        let speedup = sharded / baseline.max(1e-9);
        if threads == 4 {
            speedup_at_4 = speedup;
        }
        println!(
            "submitters={threads}: baseline {baseline:>9.0} req/s | sharded {sharded:>9.0} req/s \
             | {speedup:.2}x"
        );
        rows.push(json::obj(vec![
            ("submitters", Json::Int(threads as i64)),
            ("baseline_rps", Json::Num(baseline)),
            ("sharded_rps", Json::Num(sharded)),
            ("speedup", Json::Num(speedup)),
        ]));
    }
    let out = json::obj(vec![
        ("bench", Json::Str("scale".into())),
        ("total_workers", Json::Int(TOTAL_WORKERS as i64)),
        ("baseline_num_sgs", Json::Int(1)),
        ("sharded_num_sgs", Json::Int(4)),
        ("num_dags", Json::Int(NUM_DAGS as i64)),
        ("requests_per_submitter", Json::Int(PER_SUBMITTER as i64)),
        ("window", Json::Int(WINDOW as i64)),
        ("speedup_at_4_threads", Json::Num(speedup_at_4)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_scale.json";
    match std::fs::write(path, out.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
