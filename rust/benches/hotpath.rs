//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the discrete-event engine, the SRSF queue at depth, sandbox-table
//! operations, demand math, and whole-platform simulation throughput
//! (events/second) — the quantity that bounds how fast macrobenchmarks
//! regenerate.

use archipelago::config::{Config, SchedPolicy, MS, SEC};
use archipelago::dag::{DagId, DagSpec, FnId};
use archipelago::sandbox::SandboxTable;
use archipelago::sgs::scheduler::{QueuedFn, RequestId, SchedQueue};
use archipelago::sim::EventQueue;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::util::bench::Bench;
use archipelago::util::rng::{poisson_inv_cdf, Rng};
use archipelago::workload::{App, ArrivalProcess, DagClass};
use std::time::Instant;

fn main() {
    let bench = Bench::default();
    println!("== hot-path microbenches ==");

    // --- event queue push+pop ---
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Rng::new(1);
    for i in 0..4096 {
        q.push_at(rng.range_u64(0, 1 << 30), i);
    }
    let mut i = 4096;
    let mut r = bench.run("event_queue push+pop (depth 4096)", || {
        i += 1;
        q.push_at(q.now() + rng.range_u64(1, 1 << 20), i);
        q.pop()
    });
    println!("{}", r.report_line());

    // --- SRSF queue at depth 1024 ---
    let mut sq = SchedQueue::new(SchedPolicy::Srsf);
    for i in 0..1024u64 {
        sq.push(qf(i, &mut rng));
    }
    let mut i = 1024;
    let mut r = bench.run("srsf push+pop (depth 1024)", || {
        i += 1;
        sq.push(qf(i, &mut rng));
        sq.pop()
    });
    println!("{}", r.report_line());

    // --- sandbox table acquire/release ---
    let mut table = SandboxTable::new(32 * 1024);
    let f = FnId {
        dag: DagId(0),
        idx: 0,
    };
    for _ in 0..8 {
        table.begin_setup(f, 128).unwrap();
        table.finish_setup(f).unwrap();
    }
    let mut now = 0;
    let mut r = bench.run("sandbox acquire_warm+release", || {
        now += 1;
        table.acquire_warm(f, now).unwrap();
        table.release(f, now).unwrap();
    });
    println!("{}", r.report_line());

    // --- Poisson inverse CDF at provisioning-typical lambdas ---
    let mut lam = 10.0;
    let mut r = bench.run("poisson_inv_cdf(0.99, λ≈10..200)", || {
        lam = if lam > 200.0 { 10.0 } else { lam + 1.0 };
        poisson_inv_cdf(0.99, lam)
    });
    println!("{}", r.report_line());

    // --- whole-platform simulation throughput ---
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 4;
    cfg.cluster.workers_per_sgs = 4;
    cfg.cluster.cores_per_worker = 16;
    let apps = vec![App {
        class: DagClass::C1,
        dag: DagSpec::single(DagId(0), "bench", 50 * MS, 200 * MS, 128, 200 * MS),
        arrivals: ArrivalProcess::sinusoid(2500.0, 1200.0, 10 * SEC),
    }];
    let opts = SimOptions {
        seed: 42,
        horizon: 120 * SEC,
        warmup: 2 * SEC,
        ..SimOptions::default()
    };
    let t0 = Instant::now();
    let mut p = SimPlatform::new(cfg, apps, opts);
    let row = p.run();
    let wall = t0.elapsed().as_secs_f64();
    let events = p.events_dispatched();
    println!(
        "sim_throughput: {events} events in {wall:.2}s = {:.0} events/s \
         ({} completions, {:.0}x real-time)",
        events as f64 / wall,
        row.completed,
        120.0 / wall,
    );
}

fn qf(i: u64, rng: &mut Rng) -> QueuedFn {
    QueuedFn {
        req: RequestId(i),
        f: FnId {
            dag: DagId(0),
            idx: 0,
        },
        dag: DagId(0),
        enqueued_at: 0,
        deadline_abs: rng.range_u64(1, 1 << 30),
        remaining_work: rng.range_u64(1, 1 << 20),
        exec_time: 1,
        setup_time: 1,
        mem_mb: 128,
    }
}
