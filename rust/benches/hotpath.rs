//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//! the discrete-event engine, the SRSF queue at depth, sandbox-table
//! operations, demand math, and whole-platform simulation throughput
//! (events/second) — the quantity that bounds how fast macrobenchmarks
//! regenerate.
//!
//! Besides the human-readable report, the run writes `BENCH_hotpath.json`
//! (per-decision scheduling cost vs. the paper's §7.4 241 µs budget and
//! simulator events/sec) so perf PRs have an in-repo anchor to diff
//! against.

use archipelago::config::{Config, SchedPolicy, MS, SEC};
use archipelago::dag::{DagId, DagSpec, FnId};
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::sandbox::SandboxTable;
use archipelago::sgs::scheduler::{QueuedFn, RequestId, SchedQueue};
use archipelago::sim::EventQueue;
use archipelago::util::bench::Bench;
use archipelago::util::json::{self, Json};
use archipelago::util::rng::{poisson_inv_cdf, Rng};
use archipelago::workload::{App, ArrivalProcess, DagClass};
use std::time::Instant;

/// The paper's §7.4 median SGS scheduling-decision cost (Go prototype).
const PAPER_DECISION_BUDGET_US: f64 = 241.0;

fn main() {
    let bench = Bench::default();
    println!("== hot-path microbenches ==");

    // --- event queue push+pop ---
    let mut q: EventQueue<u64> = EventQueue::new();
    let mut rng = Rng::new(1);
    for i in 0..4096 {
        q.push_at(rng.range_u64(0, 1 << 30), i);
    }
    let mut i = 4096;
    let mut r = bench.run("event_queue push+pop (depth 4096)", || {
        i += 1;
        q.push_at(q.now() + rng.range_u64(1, 1 << 20), i);
        q.pop()
    });
    println!("{}", r.report_line());
    let event_queue_ns = r.median_ns();

    // --- SRSF queue at depth 1024 ---
    let mut sq = SchedQueue::new(SchedPolicy::Srsf);
    for i in 0..1024u64 {
        sq.push(qf(i, &mut rng));
    }
    let mut i = 1024;
    let mut r = bench.run("srsf push+pop (depth 1024)", || {
        i += 1;
        sq.push(qf(i, &mut rng));
        sq.pop()
    });
    println!("{}", r.report_line());
    let srsf_ns = r.median_ns();
    let srsf_p99_ns = r.p99_ns();

    // --- sandbox table acquire/release ---
    let mut table = SandboxTable::new(32 * 1024);
    let f = FnId {
        dag: DagId(0),
        idx: 0,
    };
    for _ in 0..8 {
        table.begin_setup(f, 128).unwrap();
        table.finish_setup(f).unwrap();
    }
    let mut now = 0;
    let mut r = bench.run("sandbox acquire_warm+release", || {
        now += 1;
        table.acquire_warm(f, now).unwrap();
        table.release(f, now).unwrap();
    });
    println!("{}", r.report_line());
    let sandbox_ns = r.median_ns();

    // --- Poisson inverse CDF at provisioning-typical lambdas ---
    let mut lam = 10.0;
    let mut r = bench.run("poisson_inv_cdf(0.99, λ≈10..200)", || {
        lam = if lam > 200.0 { 10.0 } else { lam + 1.0 };
        poisson_inv_cdf(0.99, lam)
    });
    println!("{}", r.report_line());
    let poisson_ns = r.median_ns();

    // --- whole-platform simulation throughput ---
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 4;
    cfg.cluster.workers_per_sgs = 4;
    cfg.cluster.cores_per_worker = 16;
    let apps = vec![App {
        class: DagClass::C1,
        dag: DagSpec::single(DagId(0), "bench", 50 * MS, 200 * MS, 128, 200 * MS),
        arrivals: ArrivalProcess::sinusoid(2500.0, 1200.0, 10 * SEC),
    }];
    let opts = SimOptions {
        seed: 42,
        horizon: 120 * SEC,
        warmup: 2 * SEC,
        ..SimOptions::default()
    };
    let t0 = Instant::now();
    let mut p = SimPlatform::new(cfg, apps, opts);
    let row = p.run();
    let wall = t0.elapsed().as_secs_f64();
    let events = p.events_dispatched();
    let events_per_sec = events as f64 / wall;
    println!(
        "sim_throughput: {events} events in {wall:.2}s = {events_per_sec:.0} events/s \
         ({} completions, {:.0}x real-time)",
        row.completed,
        120.0 / wall,
    );

    // The SRSF push+pop is the dominant per-decision cost of an SGS
    // scheduling decision; anchor it against the paper's budget.
    let decision_us = srsf_ns / 1_000.0;
    let out = json::obj(vec![
        ("bench", Json::Str("hotpath".into())),
        ("paper_decision_budget_us", Json::Num(PAPER_DECISION_BUDGET_US)),
        ("srsf_decision_us_median", Json::Num(decision_us)),
        ("srsf_decision_us_p99", Json::Num(srsf_p99_ns / 1_000.0)),
        (
            "decision_budget_headroom_x",
            Json::Num(PAPER_DECISION_BUDGET_US / decision_us.max(1e-9)),
        ),
        ("event_queue_op_ns_median", Json::Num(event_queue_ns)),
        ("sandbox_op_ns_median", Json::Num(sandbox_ns)),
        ("poisson_inv_cdf_ns_median", Json::Num(poisson_ns)),
        ("sim_events_per_sec", Json::Num(events_per_sec)),
        ("sim_events_total", Json::Int(events as i64)),
        ("sim_completions", Json::Int(row.completed as i64)),
        ("sim_realtime_factor", Json::Num(120.0 / wall)),
    ]);
    let path = "BENCH_hotpath.json";
    match std::fs::write(path, out.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

fn qf(i: u64, rng: &mut Rng) -> QueuedFn {
    QueuedFn {
        req: RequestId(i),
        f: FnId {
            dag: DagId(0),
            idx: 0,
        },
        dag: DagId(0),
        enqueued_at: 0,
        deadline_abs: rng.range_u64(1, 1 << 30),
        remaining_work: rng.range_u64(1, 1 << 20),
        exec_time: 1,
        setup_time: 1,
        mem_mb: 128,
    }
}
