//! End-to-end bench: regenerate every paper table/figure in quick mode
//! and report per-experiment wall time. `cargo bench` therefore exercises
//! the complete reproduction pipeline; full-horizon data comes from
//! `archipelago figures --all` (or `make figures`).

use std::time::Instant;

use archipelago::experiments::{registry, ExpContext};

fn main() {
    let dir = std::env::temp_dir().join("archipelago_bench_figures");
    std::fs::create_dir_all(&dir).unwrap();
    let mut ctx = ExpContext::new(dir.to_str().unwrap());
    ctx.quick = true;
    println!("== paper figures, quick mode ==");
    let t_all = Instant::now();
    for (id, f) in registry() {
        let t0 = Instant::now();
        let res = f(&ctx);
        let dt = t0.elapsed().as_secs_f64();
        let first_line = res.summary.lines().next().unwrap_or("");
        println!("{id:<9} {dt:>7.2}s  {first_line}");
    }
    println!("total: {:.1}s", t_all.elapsed().as_secs_f64());
    std::fs::remove_dir_all(&dir).ok();
}
