//! Open-loop end-to-end anchor (ISSUE 4): SRSF vs FIFO on the stub
//! executor, in the same process, replaying the *same* W2
//! sinusoid-modulated arrival schedule against a fresh wall-clock
//! server each — the harness form of the paper's headline claim
//! (deadline attainment under realistic load, §7.2).
//!
//! Writes `BENCH_e2e.json` next to the hotpath/scale anchors with, per
//! policy: deadline-attainment fraction, p50/p99/p99.9 e2e latency,
//! cold-start count, and requests/sec — so scheduling-policy and
//! serving-path PRs have an in-repo end-to-end number to diff against.
//!
//! The run is time-scaled 0.5× (fast-forward 2×: service times,
//! deadlines, and arrival gaps all halved together), keeping the bench
//! under ~15 s of wall time without changing the workload's shape.

use archipelago::config::SchedPolicy;
use archipelago::loadgen::{self, LoadgenOptions, StubLoadtestConfig};
use archipelago::util::json::{self, Json};

fn main() {
    println!("== open-loop e2e bench (W2 schedule, stub executor) ==");
    let base = StubLoadtestConfig {
        duration_s: 12,
        time_scale: 0.5,
        ..Default::default()
    };
    let mut rows = Vec::new();
    let mut attainment = [0.0f64; 2];
    for (i, policy) in [SchedPolicy::Srsf, SchedPolicy::Fifo].into_iter().enumerate() {
        let cfg = StubLoadtestConfig {
            policy,
            ..base.clone()
        };
        let (server, schedule) = loadgen::prepare_stub(&cfg).expect("stub server start");
        let label = loadgen::policy_label(policy);
        if i == 0 {
            println!(
                "{} requests over {:.1}s wall, {} SGS x {} workers, util {:.0}%",
                schedule.len(),
                schedule.last().map(|&(t, _)| t as f64 / 1e6).unwrap_or(0.0),
                cfg.num_sgs,
                cfg.workers,
                cfg.util * 100.0,
            );
        }
        let report = loadgen::run(&server, &schedule, label, &LoadgenOptions::default());
        println!("{}", report.format());
        attainment[i] = report.attainment;
        server.shutdown();
        rows.push(report.to_json());
    }
    println!(
        "attainment: srsf {:.2}% vs fifo {:.2}%",
        attainment[0] * 100.0,
        attainment[1] * 100.0
    );
    let out = json::obj(vec![
        ("bench", Json::Str("e2e".into())),
        ("workload", Json::Str("w2".into())),
        ("num_sgs", Json::Int(base.num_sgs as i64)),
        ("workers_per_sgs", Json::Int(base.workers as i64)),
        ("duration_virtual_s", Json::Int(base.duration_s as i64)),
        ("time_scale", Json::Num(base.time_scale)),
        ("util_target", Json::Num(base.util)),
        ("dags_per_class", Json::Int(base.dags_per_class as i64)),
        ("seed", Json::Int(base.seed as i64)),
        ("rows", Json::Arr(rows)),
    ]);
    let path = "BENCH_e2e.json";
    match std::fs::write(path, out.to_pretty()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
