//! The SGS scheduling queue: shortest-remaining-slack-first (§4.2).
//!
//! Remaining slack of a queued function request at time `t` is
//! `RS(f) = deadline_abs − t − cpl(f)` where `cpl(f)` is the critical-path
//! execution time from `f` (inclusive) to the DAG sink. Because `t`
//! shifts every queued request equally, SRSF ordering is induced by the
//! *static* key `deadline_abs − cpl(f)` — so the queue is a plain binary
//! heap with O(log n) operations and no re-keying, which is what keeps
//! SGS scheduling decisions in the hundreds of nanoseconds (§7.4 budget:
//! 241 µs median on the paper's Go prototype).
//!
//! Ties break by least remaining work (`cpl`), per the paper: finishing
//! the shortest job first yields the next scheduling opportunity sooner.
//! The same queue implements FIFO (baseline) by keying on arrival seq.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::{Micros, SchedPolicy};
use crate::dag::{DagId, FnId};

/// Platform-wide request identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// One schedulable function instance (a node of one request's DAG whose
/// dependencies are satisfied).
#[derive(Debug, Clone)]
pub struct QueuedFn {
    pub req: RequestId,
    pub f: FnId,
    pub dag: DagId,
    /// When this function became runnable at the SGS (queuing-delay base).
    pub enqueued_at: Micros,
    /// Absolute deadline of the owning request.
    pub deadline_abs: Micros,
    /// Critical-path execution time from this function to the DAG sink,
    /// inclusive of its own execution time.
    pub remaining_work: Micros,
    /// Sampled execution time for this request instance.
    pub exec_time: Micros,
    /// Cold-start cost if no warm sandbox is found.
    pub setup_time: Micros,
    pub mem_mb: u64,
}

impl QueuedFn {
    /// Static SRSF key: `deadline_abs − cpl`. Smaller = more urgent.
    /// Signed because a request can already be past its deadline.
    pub fn srsf_key(&self) -> i64 {
        self.deadline_abs as i64 - self.remaining_work as i64
    }

    /// Remaining slack at `now` (diagnostic; ordering uses the static key).
    pub fn remaining_slack(&self, now: Micros) -> i64 {
        self.srsf_key() - now as i64
    }
}

#[derive(Debug, PartialEq, Eq)]
struct HeapKey {
    primary: i64,
    tie_work: Micros,
    seq: u64,
}

impl Ord for HeapKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.primary, self.tie_work, self.seq).cmp(&(
            other.primary,
            other.tie_work,
            other.seq,
        ))
    }
}

impl PartialOrd for HeapKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// The SGS's scheduling queue.
#[derive(Debug)]
pub struct SchedQueue {
    policy: SchedPolicy,
    heap: BinaryHeap<Reverse<(HeapKey, usize)>>,
    slots: Vec<Option<QueuedFn>>,
    free_slots: Vec<usize>,
    seq: u64,
    len: usize,
}

impl SchedQueue {
    pub fn new(policy: SchedPolicy) -> Self {
        SchedQueue {
            policy,
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free_slots: Vec::new(),
            seq: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn policy(&self) -> SchedPolicy {
        self.policy
    }

    pub fn push(&mut self, q: QueuedFn) {
        let seq = self.seq;
        self.seq += 1;
        let key = match self.policy {
            SchedPolicy::Srsf => HeapKey {
                primary: q.srsf_key(),
                tie_work: q.remaining_work,
                seq,
            },
            SchedPolicy::Fifo => HeapKey {
                primary: seq as i64,
                tie_work: 0,
                seq,
            },
        };
        self.push_with_key(key, q);
    }

    /// Insert with an explicit key — used to reinsert entries skipped by
    /// [`Self::pop_feasible`] without losing their place in line (under
    /// FIFO the key *is* the arrival sequence, so re-keying would demote
    /// an infeasible-once job behind everything that arrived after it).
    fn push_with_key(&mut self, key: HeapKey, q: QueuedFn) {
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.slots[s] = Some(q);
                s
            }
            None => {
                self.slots.push(Some(q));
                self.slots.len() - 1
            }
        };
        self.heap.push(Reverse((key, slot)));
        self.len += 1;
    }

    /// Pop the most urgent queued function.
    pub fn pop(&mut self) -> Option<QueuedFn> {
        self.pop_with_key().map(|(_, q)| q)
    }

    fn pop_with_key(&mut self) -> Option<(HeapKey, QueuedFn)> {
        let Reverse((key, slot)) = self.heap.pop()?;
        let q = self.slots[slot].take().expect("heap/slot consistency");
        self.free_slots.push(slot);
        self.len -= 1;
        Some((key, q))
    }

    /// Pop the most urgent function that satisfies `feasible`, scanning at
    /// most `max_scan` candidates; infeasible candidates are reinserted
    /// with their original keys. This implements §4.2's "filters requests
    /// to only consider ones whose resource requirements are met by the
    /// current available resources" with bounded work per decision.
    pub fn pop_feasible(
        &mut self,
        max_scan: usize,
        mut feasible: impl FnMut(&QueuedFn) -> bool,
    ) -> Option<QueuedFn> {
        let mut skipped: Vec<(HeapKey, QueuedFn)> = Vec::new();
        let mut found = None;
        for _ in 0..max_scan {
            match self.pop_with_key() {
                None => break,
                Some((key, q)) => {
                    if feasible(&q) {
                        found = Some(q);
                        break;
                    }
                    skipped.push((key, q));
                }
            }
        }
        for (key, q) in skipped {
            self.push_with_key(key, q);
        }
        found
    }

    /// Drain everything (SGS failure handling: requeue to other SGSs).
    pub fn drain(&mut self) -> Vec<QueuedFn> {
        let mut out = Vec::with_capacity(self.len);
        while let Some(q) = self.pop() {
            out.push(q);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn qf(req: u64, deadline_abs: Micros, cpl: Micros) -> QueuedFn {
        QueuedFn {
            req: RequestId(req),
            f: FnId {
                dag: DagId(0),
                idx: 0,
            },
            dag: DagId(0),
            enqueued_at: 0,
            deadline_abs,
            remaining_work: cpl,
            exec_time: cpl,
            setup_time: 100 * MS,
            mem_mb: 128,
        }
    }

    #[test]
    fn srsf_orders_by_static_slack_key() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        q.push(qf(1, 1000, 100)); // key 900
        q.push(qf(2, 500, 100)); // key 400  <- most urgent
        q.push(qf(3, 800, 300)); // key 500
        assert_eq!(q.pop().unwrap().req, RequestId(2));
        assert_eq!(q.pop().unwrap().req, RequestId(3));
        assert_eq!(q.pop().unwrap().req, RequestId(1));
        assert!(q.pop().is_none());
    }

    #[test]
    fn srsf_tie_breaks_by_least_remaining_work() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        q.push(qf(1, 1000, 400)); // key 600, work 400
        q.push(qf(2, 700, 100)); // key 600, work 100 <- wins tie
        assert_eq!(q.pop().unwrap().req, RequestId(2));
    }

    #[test]
    fn negative_slack_sorts_first() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        q.push(qf(1, 1000, 100));
        q.push(qf(2, 50, 100)); // key -50: past deadline, most urgent
        assert_eq!(q.pop().unwrap().req, RequestId(2));
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let mut q = SchedQueue::new(SchedPolicy::Fifo);
        q.push(qf(1, 1000, 100));
        q.push(qf(2, 5, 1)); // urgent but FIFO ignores that
        q.push(qf(3, 800, 300));
        assert_eq!(q.pop().unwrap().req, RequestId(1));
        assert_eq!(q.pop().unwrap().req, RequestId(2));
        assert_eq!(q.pop().unwrap().req, RequestId(3));
    }

    #[test]
    fn pop_feasible_skips_and_reinserts() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        q.push(qf(1, 100, 10)); // key 90, most urgent but infeasible
        q.push(qf(2, 500, 10)); // key 490
        let got = q.pop_feasible(8, |c| c.req != RequestId(1)).unwrap();
        assert_eq!(got.req, RequestId(2));
        assert_eq!(q.len(), 1);
        // the skipped one is still there with its original priority
        assert_eq!(q.pop().unwrap().req, RequestId(1));
    }

    #[test]
    fn fifo_skipped_entry_keeps_its_place_in_line() {
        // Regression: reinserting a skipped entry used to assign a fresh
        // seq, so under FIFO an infeasible-once job silently lost its
        // place behind later arrivals.
        let mut q = SchedQueue::new(SchedPolicy::Fifo);
        q.push(qf(1, 1000, 100)); // arrived first, infeasible this round
        q.push(qf(2, 1000, 100));
        q.push(qf(3, 1000, 100));
        let got = q.pop_feasible(8, |c| c.req != RequestId(1)).unwrap();
        assert_eq!(got.req, RequestId(2));
        // request 1 must still be ahead of request 3
        assert_eq!(q.pop().unwrap().req, RequestId(1));
        assert_eq!(q.pop().unwrap().req, RequestId(3));
    }

    #[test]
    fn srsf_skipped_entry_keeps_original_tie_order() {
        // Same guarantee under SRSF: a skipped entry ties with an equal-
        // key peer by its *original* arrival seq, not the reinsert time.
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        q.push(qf(1, 1000, 100)); // key 900, seq 0
        q.push(qf(2, 500, 100)); // key 400, feasible
        q.push(qf(3, 1000, 100)); // key 900, seq 2
        let got = q.pop_feasible(8, |c| c.req != RequestId(1)).unwrap();
        assert_eq!(got.req, RequestId(2));
        assert_eq!(q.pop().unwrap().req, RequestId(1), "original seq wins tie");
        assert_eq!(q.pop().unwrap().req, RequestId(3));
    }

    #[test]
    fn pop_feasible_bounded_scan() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        for i in 0..10 {
            q.push(qf(i, 100 + i, 10));
        }
        // nothing feasible within scan depth 3
        assert!(q.pop_feasible(3, |_| false).is_none());
        assert_eq!(q.len(), 10, "all candidates reinserted");
    }

    #[test]
    fn remaining_slack_decreases_with_time() {
        let q = qf(1, 1000, 100);
        assert_eq!(q.remaining_slack(0), 900);
        assert_eq!(q.remaining_slack(500), 400);
        assert_eq!(q.remaining_slack(1500), -600);
    }

    #[test]
    fn drain_returns_all() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        for i in 0..5 {
            q.push(qf(i, 1000, 100));
        }
        let drained = q.drain();
        assert_eq!(drained.len(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn slot_reuse_keeps_consistency() {
        let mut q = SchedQueue::new(SchedPolicy::Srsf);
        for round in 0..10 {
            for i in 0..100u64 {
                q.push(qf(round * 100 + i, 1000 + i, 10));
            }
            for _ in 0..100 {
                assert!(q.pop().is_some());
            }
        }
        assert!(q.is_empty());
        assert!(q.slots.len() <= 101, "slots recycled, not grown");
    }
}
