//! The Semi-Global Scheduler (§4): one SGS exclusively manages a worker
//! pool, schedules DAG-function requests deadline-aware (SRSF), and
//! proactively manages sandboxes (demand estimation → even placement →
//! soft/hard eviction).
//!
//! The struct is simulation-agnostic: methods take `now` and return
//! *effects* ([`Dispatch`], [`SetupStart`]) that the driver (discrete-
//! event platform or real-time runtime) turns into completion events or
//! thread work. All policy logic lives in the submodules and is unit- and
//! property-tested in isolation.

pub mod estimator;
pub mod eviction;
pub mod placement;
pub mod scheduler;

use std::collections::HashMap;

use crate::config::{Micros, SgsConfig};
use crate::dag::{DagId, DagRegistry, FnId};
use crate::worker::{WorkerId, WorkerPool};

pub use estimator::{DemandReport, Estimator};
pub use scheduler::{QueuedFn, RequestId, SchedQueue};

/// SGS index within the scheduling service.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SgsId(pub u16);

/// A scheduling decision: run `f` of `req` on `worker`.
#[derive(Debug, Clone)]
pub struct Dispatch {
    pub req: RequestId,
    pub f: FnId,
    pub worker: WorkerId,
    /// True if the request found no warm sandbox and pays setup time.
    pub cold: bool,
    /// Time the function will finish (start + overheads + exec).
    pub finish_at: Micros,
    /// Queuing delay this function experienced at the SGS.
    pub queue_delay: Micros,
}

/// A proactive sandbox setup started; becomes warm at `done_at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SetupStart {
    pub worker: WorkerId,
    pub f: FnId,
    pub done_at: Micros,
}

/// Scan depth for the memory-feasibility filter in the dispatch loop.
const FEASIBILITY_SCAN: usize = 16;

/// One semi-global scheduler and its worker pool.
#[derive(Debug)]
pub struct Sgs {
    pub id: SgsId,
    pub pool: WorkerPool,
    pub queue: SchedQueue,
    pub estimator: Estimator,
    cfg: SgsConfig,
    /// Current demand estimate per function (drives eviction fairness
    /// and the allocate/soft-evict reconciliation).
    estimates: HashMap<FnId, u32>,
    /// Total cold starts observed (metric).
    cold_starts: u64,
    /// Total dispatches (metric).
    dispatches: u64,
    alive: bool,
}

impl Sgs {
    pub fn new(id: SgsId, workers: usize, cores: u32, pool_mb: u64, cfg: SgsConfig) -> Self {
        Sgs {
            id,
            pool: WorkerPool::new(workers, cores, pool_mb),
            queue: SchedQueue::new(cfg.sched_policy),
            estimator: Estimator::new(
                cfg.estimate_interval,
                cfg.rate_ewma_alpha,
                cfg.qdelay_ewma_alpha,
                cfg.qdelay_window,
                cfg.sla_quantile,
                cfg.provision_margin,
            ),
            cfg,
            estimates: HashMap::new(),
            cold_starts: 0,
            dispatches: 0,
            alive: true,
        }
    }

    pub fn is_alive(&self) -> bool {
        self.alive
    }

    pub fn cold_starts(&self) -> u64 {
        self.cold_starts
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    pub fn config(&self) -> &SgsConfig {
        &self.cfg
    }

    /// Demand estimate for a function (0 if untracked).
    pub fn estimate(&self, f: FnId) -> u32 {
        *self.estimates.get(&f).unwrap_or(&0)
    }

    /// Distinct warm (active) sandbox kinds per worker, in pool order —
    /// the observability view the realtime server exposes per worker
    /// thread.
    pub fn warm_kind_counts(&self) -> Vec<usize> {
        self.pool
            .workers
            .iter()
            .map(|w| {
                w.sandboxes
                    .iter()
                    .filter(|(_, set)| set.active() > 0)
                    .count()
            })
            .collect()
    }

    /// Total proactive (active) sandboxes for a DAG across the pool —
    /// the lottery-ticket count piggybacked to the LBS (§5.2.3).
    pub fn dag_sandbox_count(&self, dag: &crate::dag::DagSpec) -> u32 {
        (0..dag.len() as u16)
            .map(|i| self.pool.active_count(dag.fn_id(i)))
            .sum()
    }

    /// Enqueue a runnable function of a request. `is_root_arrival` marks
    /// the first function(s) of a request for arrival-rate accounting.
    pub fn enqueue(&mut self, q: QueuedFn, is_root_arrival: bool) {
        if is_root_arrival {
            self.estimator.record_arrival(q.dag);
        }
        self.queue.push(q);
    }

    /// Work-conserving dispatch loop: schedule queued functions onto free
    /// cores until either runs out. Returns the dispatches made;
    /// completion events are the caller's job.
    pub fn try_dispatch(&mut self, now: Micros) -> Vec<Dispatch> {
        let mut out = Vec::new();
        self.try_dispatch_into(now, &mut out);
        out
    }

    /// Allocation-free variant: dispatches are appended to `out`
    /// (cleared first). The platform's event loop reuses one buffer.
    pub fn try_dispatch_into(&mut self, now: Micros, out: &mut Vec<Dispatch>) {
        out.clear();
        loop {
            if self.queue.is_empty() || !self.pool.any_free_core() {
                break;
            }
            let pool = &self.pool;
            let candidate = self.queue.pop_feasible(FEASIBILITY_SCAN, |q| {
                pool.pick_dispatch_worker(q.f, q.mem_mb).is_some()
            });
            let Some(q) = candidate else { break };
            let (wid, warm) = self
                .pool
                .pick_dispatch_worker(q.f, q.mem_mb)
                .expect("feasibility checked");
            let worker = self.pool.get_mut(wid);
            let mut cold = !warm;
            if warm {
                worker
                    .sandboxes
                    .acquire_warm(q.f, now)
                    .expect("picked for warm");
            } else if worker.sandboxes.soft(q.f) > 0 {
                // Unpause a soft-evicted sandbox of this function — free
                // (§4.3.3's unmark; what a real execution manager does
                // with a paused container rather than cold-starting next
                // to it).
                worker
                    .sandboxes
                    .soft_revive_one(q.f)
                    .expect("soft count checked");
                worker
                    .sandboxes
                    .acquire_warm(q.f, now)
                    .expect("revived to warm");
                cold = false;
            } else {
                // Cold start: make room if needed, then allocate
                // reactively — the request pays q.setup_time. If the
                // worker holds soft-evicted sandboxes of this very
                // function, evict one of those (its memory is exactly
                // the right size and it was surplus by definition);
                // otherwise fall back to the policy victim.
                if !worker.sandboxes.has_pool_mem(q.mem_mb)
                    && worker.sandboxes.soft(q.f) > 0
                {
                    worker
                        .sandboxes
                        .hard_evict_one(q.f)
                        .expect("soft implies evictable");
                }
                let fits = worker.sandboxes.has_pool_mem(q.mem_mb)
                    || eviction::evict_until_fits(
                        worker,
                        &self.estimates,
                        q.f,
                        q.mem_mb,
                        self.cfg.eviction,
                    )
                    .is_some();
                if !fits {
                    // Everything on this worker is busy or protected;
                    // requeue and stop this round (retried on the next
                    // completion or setup event).
                    self.queue.push(q);
                    break;
                }
                worker
                    .sandboxes
                    .acquire_cold(q.f, q.mem_mb, now)
                    .expect("room was made");
                self.cold_starts += 1;
            }
            let warm = !cold;
            worker.occupy_core();
            let queue_delay = now.saturating_sub(q.enqueued_at);
            self.estimator.record_qdelay(q.dag, queue_delay);
            let setup = if warm { 0 } else { q.setup_time };
            let finish_at = now + self.cfg.sched_overhead + setup + q.exec_time;
            self.dispatches += 1;
            out.push(Dispatch {
                req: q.req,
                f: q.f,
                worker: wid,
                cold: !warm,
                finish_at,
                queue_delay,
            });
        }
    }

    /// A dispatched function finished: free the core, return the sandbox
    /// to warm-idle.
    pub fn complete(&mut self, worker: WorkerId, f: FnId, now: Micros) {
        let w = self.pool.get_mut(worker);
        if !w.is_alive() {
            return; // worker died while the function ran; nothing to free
        }
        w.release_core();
        w.sandboxes
            .release(f, now)
            .expect("completion implies a busy sandbox");
    }

    /// A proactive setup finished: the sandbox becomes warm.
    pub fn setup_done(&mut self, worker: WorkerId, f: FnId) {
        let w = self.pool.get_mut(worker);
        if !w.is_alive() {
            return; // setup was lost with the worker
        }
        w.sandboxes
            .finish_setup(f)
            .expect("setup_done implies setting_up");
    }

    /// Estimation tick (§4.3.1): close the interval, recompute per-
    /// function demand for every tracked DAG, and reconcile allocations
    /// per Pseudocode 1. Returns the proactive setups started.
    pub fn estimator_tick(&mut self, now: Micros, registry: &DagRegistry) -> Vec<SetupStart> {
        let reports = self.estimator.tick();
        let mut setups = Vec::new();
        for (dag_id, report) in reports {
            let dag = registry.get(dag_id);
            for idx in 0..dag.len() as u16 {
                let f = dag.fn_id(idx);
                let spec = &dag.functions[idx as usize];
                let new_demand = self.estimator.function_demand(&report, spec.exec_time);
                setups.extend(self.reconcile_function(
                    now,
                    f,
                    new_demand,
                    spec.mem_mb,
                    spec.setup_time,
                ));
            }
        }
        setups
    }

    /// Pseudocode 1 `SandboxManagement` for one function: allocate the
    /// shortfall or soft-evict the surplus. The "old demand" (M[D.id])
    /// is the *actual* active sandbox count, which also folds in
    /// reactively-created sandboxes from cold-start dispatches — so the
    /// allocation always converges to the estimate (Fig 8b's tracking
    /// behaviour) instead of drifting above it.
    fn reconcile_function(
        &mut self,
        now: Micros,
        f: FnId,
        new_demand: u32,
        mem_mb: u64,
        setup_time: Micros,
    ) -> Vec<SetupStart> {
        let actual = self.pool.active_count(f);
        if new_demand == 0 {
            self.estimates.remove(&f);
        } else {
            self.estimates.insert(f, new_demand);
        }
        let mut setups = Vec::new();
        if new_demand > actual {
            for _ in 0..(new_demand - actual) {
                if let Some(s) = self.allocate_one(now, f, mem_mb, setup_time) {
                    setups.push(s);
                }
            }
        } else if new_demand < actual {
            for _ in 0..(actual - new_demand) {
                if !self.trim_one(f) {
                    break;
                }
            }
        }
        setups
    }

    /// Remove one surplus sandbox of `f`. Under even placement the
    /// surplus is *soft-evicted* (kept memory-resident for free revival
    /// — the paper's lazy eviction). Under the packed ablation it is
    /// hard-evicted: a placement that packs to minimize memory footprint
    /// reclaims the spread-out memory, which is exactly what loses the
    /// statistical multiplexing Fig 9 measures.
    fn trim_one(&mut self, f: FnId) -> bool {
        match placement::choose_soft_evict_worker(&self.pool, f, self.cfg.placement) {
            Some(wid) => {
                let w = self.pool.get_mut(wid);
                match self.cfg.placement {
                    crate::config::PlacementPolicy::Even => {
                        w.sandboxes
                            .soft_evict_one(f)
                            .expect("choose_soft_evict_worker guarantees warm");
                    }
                    crate::config::PlacementPolicy::Packed => {
                        w.sandboxes
                            .hard_evict_one(f)
                            .expect("warm implies evictable");
                    }
                }
                true
            }
            None => false,
        }
    }

    /// Pseudocode 1 `AllocateSandboxes` body for a single sandbox:
    /// min-count worker → revive a soft-evicted sandbox if present →
    /// else allocate (hard-evicting per policy if the pool is full).
    /// Returns `None` when the sandbox came from a (free) revival or
    /// when no worker can host it.
    fn allocate_one(
        &mut self,
        now: Micros,
        f: FnId,
        mem_mb: u64,
        setup_time: Micros,
    ) -> Option<SetupStart> {
        let wid = placement::choose_allocation_worker(&self.pool, f, mem_mb, self.cfg.placement)?;
        let policy = self.cfg.eviction;
        let worker = self.pool.get_mut(wid);
        // Preferentially revive a soft-evicted sandbox: zero overhead.
        if worker.sandboxes.soft(f) > 0 {
            worker
                .sandboxes
                .soft_revive_one(f)
                .expect("soft count checked");
            return None;
        }
        if !worker.sandboxes.has_pool_mem(mem_mb) {
            // Hard-evict per policy; if nothing is evictable the
            // allocation is skipped this tick (retried next tick).
            eviction::evict_until_fits(worker, &self.estimates, f, mem_mb, policy)?;
        }
        worker
            .sandboxes
            .begin_setup(f, mem_mb)
            .expect("space ensured");
        Some(SetupStart {
            worker: wid,
            f,
            done_at: now + setup_time,
        })
    }

    /// Soft-evict one sandbox of `f` (site chosen per placement policy).
    /// Returns false when no warm sandbox remains to evict.
    fn soft_evict_one(&mut self, f: FnId) -> bool {
        match placement::choose_soft_evict_worker(&self.pool, f, self.cfg.placement) {
            Some(wid) => {
                self.pool
                    .get_mut(wid)
                    .sandboxes
                    .soft_evict_one(f)
                    .expect("choose_soft_evict_worker guarantees warm");
                true
            }
            None => false,
        }
    }

    /// LBS scale-out priming (§5.2.3): proactively allocate `target`
    /// sandboxes per function of `dag` and seed the rate estimate so the
    /// next estimator tick doesn't immediately soft-evict them.
    pub fn prime_dag(
        &mut self,
        now: Micros,
        dag_id: DagId,
        target: u32,
        expected_rate_per_interval: f64,
        registry: &DagRegistry,
    ) -> Vec<SetupStart> {
        self.estimator.seed_rate(dag_id, expected_rate_per_interval);
        let dag = registry.get(dag_id);
        let mut setups = Vec::new();
        for idx in 0..dag.len() as u16 {
            let f = dag.fn_id(idx);
            let spec = &dag.functions[idx as usize];
            setups.extend(self.reconcile_function(
                now,
                f,
                self.estimate(f).max(target),
                spec.mem_mb,
                spec.setup_time,
            ));
        }
        setups
    }

    /// Fully dissociate a DAG from this SGS (post scale-in drain):
    /// soft-evict all its warm sandboxes and drop estimator state.
    pub fn release_dag(&mut self, dag_id: DagId, registry: &DagRegistry) {
        let dag = registry.get(dag_id);
        for idx in 0..dag.len() as u16 {
            let f = dag.fn_id(idx);
            while self.soft_evict_one(f) {}
            self.estimates.remove(&f);
        }
        self.estimator.forget(dag_id);
    }

    /// Fail-stop a worker (§6.1): the SGS updates its cluster view. The
    /// caller is responsible for re-enqueueing the tasks that were
    /// running there.
    pub fn fail_worker(&mut self, worker: WorkerId) {
        self.pool.get_mut(worker).fail();
    }

    pub fn recover_worker(&mut self, worker: WorkerId) {
        self.pool.get_mut(worker).recover();
    }

    /// Fail-stop the whole SGS; state is recoverable from the external
    /// store (§6.1). Queue contents are returned for re-routing.
    pub fn fail(&mut self) -> Vec<QueuedFn> {
        self.alive = false;
        self.queue.drain()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        self.pool.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EvictionPolicy, PlacementPolicy, SchedPolicy, MS};
    use crate::dag::DagSpec;

    fn test_cfg() -> SgsConfig {
        SgsConfig {
            sched_policy: SchedPolicy::Srsf,
            placement: PlacementPolicy::Even,
            eviction: EvictionPolicy::Fair,
            estimate_interval: 100 * MS,
            rate_ewma_alpha: 0.5,
            sla_quantile: 0.99,
            provision_margin: 0.0,
            qdelay_ewma_alpha: 0.3,
            qdelay_window: 4,
            sched_overhead: 0,
        }
    }

    fn reg_one_dag() -> DagRegistry {
        let mut reg = DagRegistry::new();
        reg.register(DagSpec::single(
            DagId(0),
            "d0",
            50 * MS,
            200 * MS,
            128,
            150 * MS,
        ));
        reg
    }

    fn qfn(req: u64, dag: &DagSpec, now: Micros) -> QueuedFn {
        QueuedFn {
            req: RequestId(req),
            f: dag.fn_id(0),
            dag: dag.id,
            enqueued_at: now,
            deadline_abs: now + dag.deadline,
            remaining_work: dag.cpl[0],
            exec_time: dag.functions[0].exec_time,
            setup_time: dag.functions[0].setup_time,
            mem_mb: dag.functions[0].mem_mb,
        }
    }

    #[test]
    fn cold_dispatch_pays_setup() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 2, 2, 4096, test_cfg());
        sgs.enqueue(qfn(1, dag, 0), true);
        let d = sgs.try_dispatch(0);
        assert_eq!(d.len(), 1);
        assert!(d[0].cold);
        assert_eq!(d[0].finish_at, 200 * MS + 50 * MS);
        assert_eq!(sgs.cold_starts(), 1);
    }

    #[test]
    fn warm_dispatch_skips_setup() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 2, 2, 4096, test_cfg());
        // pre-warm one sandbox on worker 0
        sgs.pool
            .get_mut(WorkerId(0))
            .sandboxes
            .begin_setup(dag.fn_id(0), 128)
            .unwrap();
        sgs.pool
            .get_mut(WorkerId(0))
            .sandboxes
            .finish_setup(dag.fn_id(0))
            .unwrap();
        assert_eq!(sgs.warm_kind_counts(), vec![1, 0]);
        sgs.enqueue(qfn(1, dag, 0), true);
        let d = sgs.try_dispatch(1000);
        assert_eq!(d.len(), 1);
        assert!(!d[0].cold);
        assert_eq!(d[0].worker, WorkerId(0));
        assert_eq!(d[0].finish_at, 1000 + 50 * MS);
        assert_eq!(d[0].queue_delay, 1000);
        assert_eq!(sgs.cold_starts(), 0);
    }

    #[test]
    fn dispatch_stops_at_core_saturation() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 1, 2, 4096, test_cfg());
        for i in 0..5 {
            sgs.enqueue(qfn(i, dag, 0), true);
        }
        let d = sgs.try_dispatch(0);
        assert_eq!(d.len(), 2, "only 2 cores");
        assert_eq!(sgs.queue.len(), 3);
        // completion frees a core and the next dispatch proceeds
        sgs.complete(d[0].worker, d[0].f, d[0].finish_at);
        let d2 = sgs.try_dispatch(d[0].finish_at);
        assert_eq!(d2.len(), 1);
        // sandbox was reused: second dispatch on that worker is warm
        assert!(!d2[0].cold);
    }

    #[test]
    fn estimator_tick_allocates_proactively() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 4, 2, 4096, test_cfg());
        // simulate a burst of arrivals
        for i in 0..40 {
            sgs.enqueue(qfn(i, dag, 0), true);
        }
        let setups = sgs.estimator_tick(100 * MS, &reg);
        assert!(!setups.is_empty());
        // even placement: spread across workers
        let mut per_worker = [0u32; 4];
        for s in &setups {
            per_worker[s.worker.0 as usize] += 1;
            assert_eq!(s.done_at, 100 * MS + 200 * MS);
            sgs.setup_done(s.worker, s.f);
        }
        let max = per_worker.iter().max().unwrap();
        let min = per_worker.iter().min().unwrap();
        assert!(max - min <= 1, "even spread, got {per_worker:?}");
        assert_eq!(
            sgs.dag_sandbox_count(dag),
            setups.len() as u32
        );
    }

    #[test]
    fn demand_drop_soft_evicts_then_revives_free() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let f = dag.fn_id(0);
        let mut sgs = Sgs::new(SgsId(0), 2, 2, 4096, test_cfg());
        // build up demand
        for i in 0..30 {
            sgs.enqueue(qfn(i, dag, 0), true);
        }
        let setups = sgs.estimator_tick(0, &reg);
        for s in &setups {
            sgs.setup_done(s.worker, s.f);
        }
        let high = sgs.pool.active_count(f);
        assert!(high > 0);
        // demand collapses over several ticks
        for _ in 0..30 {
            sgs.estimator_tick(0, &reg);
        }
        assert!(sgs.pool.active_count(f) < high);
        assert!(sgs.pool.soft_count(f) > 0, "excess soft-evicted, not hard");
        // demand returns: sandboxes revive without new setups
        let soft_before = sgs.pool.soft_count(f);
        for i in 100..130 {
            sgs.enqueue(qfn(i, dag, 0), true);
        }
        let new_setups = sgs.estimator_tick(0, &reg);
        assert!(sgs.pool.soft_count(f) < soft_before, "revived from soft");
        // revivals happen before any new setups
        assert!(new_setups.len() < 30);
    }

    #[test]
    fn prime_dag_allocates_target() {
        let reg = reg_one_dag();
        let mut sgs = Sgs::new(SgsId(0), 4, 2, 4096, test_cfg());
        let setups = sgs.prime_dag(0, DagId(0), 8, 6.0, &reg);
        assert_eq!(setups.len(), 8);
        // priming seeded the estimator so an immediate tick with zero
        // arrivals does not collapse the allocation to zero
        for s in &setups {
            sgs.setup_done(s.worker, s.f);
        }
        sgs.estimator_tick(0, &reg);
        let dag = reg.get(DagId(0));
        assert!(
            sgs.dag_sandbox_count(dag) > 0,
            "seeded rate keeps some sandboxes alive"
        );
    }

    #[test]
    fn release_dag_clears_state() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 2, 2, 4096, test_cfg());
        let setups = sgs.prime_dag(0, DagId(0), 4, 3.0, &reg);
        for s in &setups {
            sgs.setup_done(s.worker, s.f);
        }
        sgs.release_dag(DagId(0), &reg);
        assert_eq!(sgs.dag_sandbox_count(dag), 0);
        assert_eq!(sgs.estimate(dag.fn_id(0)), 0);
        assert!(sgs.estimator.qdelay(DagId(0)).is_none());
    }

    #[test]
    fn worker_failure_is_survivable() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 2, 1, 4096, test_cfg());
        sgs.enqueue(qfn(1, dag, 0), true);
        sgs.enqueue(qfn(2, dag, 0), true);
        let d = sgs.try_dispatch(0);
        assert_eq!(d.len(), 2);
        sgs.fail_worker(d[0].worker);
        // completion on the dead worker is a no-op, not a panic
        sgs.complete(d[0].worker, d[0].f, d[0].finish_at);
        // the other worker still completes normally
        sgs.complete(d[1].worker, d[1].f, d[1].finish_at);
        sgs.check_invariants().unwrap();
    }

    #[test]
    fn sgs_failure_drains_queue() {
        let reg = reg_one_dag();
        let dag = reg.get(DagId(0));
        let mut sgs = Sgs::new(SgsId(0), 1, 1, 4096, test_cfg());
        for i in 0..3 {
            sgs.enqueue(qfn(i, dag, 0), true);
        }
        sgs.try_dispatch(0); // one runs
        let orphaned = sgs.fail();
        assert_eq!(orphaned.len(), 2);
        assert!(!sgs.is_alive());
    }

    #[test]
    fn fifo_policy_config_respected() {
        let mut cfg = test_cfg();
        cfg.sched_policy = SchedPolicy::Fifo;
        let sgs = Sgs::new(SgsId(0), 1, 1, 4096, cfg);
        assert_eq!(sgs.queue.policy(), SchedPolicy::Fifo);
    }
}
