//! Proactive sandbox placement across a worker pool (§4.3.2, Fig 4b).
//!
//! Archipelago's policy is **even spreading**: each new sandbox goes to
//! the alive worker holding the fewest sandboxes of that function, which
//! maximizes the probability that a future request finds a free core
//! *and* a warm sandbox on the same machine (statistical multiplexing —
//! Fig 9 shows packing misses ~70% of deadlines at load peaks).
//!
//! The ablation policy **packed** concentrates sandboxes on as few
//! workers as possible (what a memory-minimizing placement would do).
//! Soft-eviction site selection is the mirror image: take from the
//! worker with the *most* sandboxes of the function (§4.3.3).

use crate::config::PlacementPolicy;
use crate::dag::FnId;
use crate::worker::{WorkerId, WorkerPool};

/// Choose the worker to host one new proactive sandbox of `f`.
///
/// Even: min active-sandbox count; ties by most free pool memory, then
/// lowest id. Packed: max active-sandbox count among workers that can
/// still fit the sandbox without eviction, falling back to even's choice
/// when nobody fits (so packing still works when the pool saturates).
pub fn choose_allocation_worker(
    pool: &WorkerPool,
    f: FnId,
    mem_mb: u64,
    policy: PlacementPolicy,
) -> Option<WorkerId> {
    match policy {
        PlacementPolicy::Even => min_count_worker(pool, f),
        PlacementPolicy::Packed => {
            let mut best: Option<(u32, WorkerId)> = None;
            for w in &pool.workers {
                if !w.is_alive() {
                    continue;
                }
                let fits = w.sandboxes.has_pool_mem(mem_mb)
                    || w.sandboxes.soft(f) > 0; // revival needs no memory
                if !fits {
                    continue;
                }
                let count = w.sandboxes.active(f);
                let better = match best {
                    None => true,
                    Some((c, id)) => count > c || (count == c && w.id.0 < id.0),
                };
                if better {
                    best = Some((count, w.id));
                }
            }
            best.map(|(_, id)| id).or_else(|| min_count_worker(pool, f))
        }
    }
}

fn min_count_worker(pool: &WorkerPool, f: FnId) -> Option<WorkerId> {
    let mut best: Option<(u32, u64, WorkerId)> = None;
    for w in &pool.workers {
        if !w.is_alive() {
            continue;
        }
        let count = w.sandboxes.active(f);
        let free = w.sandboxes.pool_free_mb();
        let better = match best {
            None => true,
            Some((c, fr, id)) => {
                count < c
                    || (count == c && free > fr)
                    || (count == c && free == fr && w.id.0 < id.0)
            }
        };
        if better {
            best = Some((count, free, w.id));
        }
    }
    best.map(|(_, _, id)| id)
}

/// Choose the worker to *soft-evict* one sandbox of `f` from. The
/// eviction site mirrors the placement policy: under **even** placement
/// the max-count worker sheds first — "the SGS follows a process similar
/// to the placement approach ... with the only difference being that it
/// selects the worker(s) that have the maximum sandboxes of this type"
/// (§4.3.3) — which keeps the spread balanced. Under the **packed**
/// ablation the min-count worker sheds first, so the policy keeps
/// concentrating sandboxes (and reactively-created spread-out sandboxes
/// are stripped at every demand trough — the Fig 9 behaviour).
pub fn choose_soft_evict_worker(
    pool: &WorkerPool,
    f: FnId,
    policy: PlacementPolicy,
) -> Option<WorkerId> {
    let mut best: Option<(u32, WorkerId)> = None;
    for w in &pool.workers {
        if !w.is_alive() {
            continue;
        }
        let evictable = w.sandboxes.warm_idle(f);
        if evictable == 0 {
            continue;
        }
        let count = w.sandboxes.active(f);
        let better = match (policy, best) {
            (_, None) => true,
            (PlacementPolicy::Even, Some((c, id))) => {
                count > c || (count == c && w.id.0 < id.0)
            }
            (PlacementPolicy::Packed, Some((c, id))) => {
                count < c || (count == c && w.id.0 < id.0)
            }
        };
        if better {
            best = Some((count, w.id));
        }
    }
    best.map(|(_, id)| id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagId;

    fn fid(i: u16) -> FnId {
        FnId {
            dag: DagId(0),
            idx: i,
        }
    }

    fn add_warm(pool: &mut WorkerPool, wid: u16, f: FnId, n: u32) {
        for _ in 0..n {
            pool.get_mut(WorkerId(wid))
                .sandboxes
                .begin_setup(f, 128)
                .unwrap();
            pool.get_mut(WorkerId(wid))
                .sandboxes
                .finish_setup(f)
                .unwrap();
        }
    }

    #[test]
    fn even_picks_min_count_worker() {
        let mut p = WorkerPool::new(3, 4, 4096);
        add_warm(&mut p, 0, fid(0), 2);
        add_warm(&mut p, 1, fid(0), 1);
        // worker 2 has zero
        let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even);
        assert_eq!(w, Some(WorkerId(2)));
    }

    #[test]
    fn even_spreads_round_robin_when_equal() {
        let mut p = WorkerPool::new(4, 4, 4096);
        let mut counts = vec![0u32; 4];
        for _ in 0..8 {
            let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even)
                .unwrap();
            counts[w.0 as usize] += 1;
            add_warm(&mut p, w.0, fid(0), 1);
        }
        assert_eq!(counts, vec![2, 2, 2, 2], "even spread");
    }

    #[test]
    fn even_only_counts_this_function() {
        let mut p = WorkerPool::new(2, 4, 4096);
        add_warm(&mut p, 0, fid(1), 5); // other function, ignored for f0 count
        add_warm(&mut p, 1, fid(0), 1);
        let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even);
        assert_eq!(w, Some(WorkerId(0)));
    }

    #[test]
    fn packed_concentrates_on_max_count_worker() {
        let mut p = WorkerPool::new(3, 4, 4096);
        add_warm(&mut p, 1, fid(0), 2);
        for _ in 0..4 {
            let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Packed)
                .unwrap();
            assert_eq!(w, WorkerId(1));
            add_warm(&mut p, 1, fid(0), 1);
        }
    }

    #[test]
    fn packed_spills_when_pool_full() {
        let mut p = WorkerPool::new(2, 4, 256); // room for 2 sandboxes each
        add_warm(&mut p, 0, fid(0), 2); // worker 0 pool full
        let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Packed);
        assert_eq!(w, Some(WorkerId(1)));
    }

    #[test]
    fn dead_workers_excluded() {
        let mut p = WorkerPool::new(2, 4, 4096);
        p.get_mut(WorkerId(0)).fail();
        let w = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even);
        assert_eq!(w, Some(WorkerId(1)));
        p.get_mut(WorkerId(1)).fail();
        assert_eq!(
            choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even),
            None
        );
    }

    #[test]
    fn soft_evict_takes_from_max_worker() {
        let mut p = WorkerPool::new(3, 4, 4096);
        add_warm(&mut p, 0, fid(0), 1);
        add_warm(&mut p, 1, fid(0), 3);
        add_warm(&mut p, 2, fid(0), 2);
        let w = choose_soft_evict_worker(&p, fid(0), PlacementPolicy::Even);
        assert_eq!(w, Some(WorkerId(1)));
    }

    #[test]
    fn soft_evict_requires_warm_idle() {
        let mut p = WorkerPool::new(2, 4, 4096);
        add_warm(&mut p, 0, fid(0), 1);
        p.get_mut(WorkerId(0))
            .sandboxes
            .acquire_warm(fid(0), 0)
            .unwrap(); // now busy, not evictable
        assert_eq!(choose_soft_evict_worker(&p, fid(0), PlacementPolicy::Even), None);
    }

    #[test]
    fn packed_soft_evict_takes_from_min_worker() {
        let mut p = WorkerPool::new(3, 4, 4096);
        add_warm(&mut p, 0, fid(0), 1);
        add_warm(&mut p, 1, fid(0), 3);
        let w = choose_soft_evict_worker(&p, fid(0), PlacementPolicy::Packed);
        assert_eq!(w, Some(WorkerId(0)), "packing strips the spread-out one");
    }

    #[test]
    fn soft_evict_then_allocate_rebalances() {
        // soft-evict takes from max, allocation prefers min — together
        // they keep the spread even (the §4.3.3 "balances ... to the
        // extent possible" claim).
        let mut p = WorkerPool::new(2, 4, 4096);
        add_warm(&mut p, 0, fid(0), 4);
        add_warm(&mut p, 1, fid(0), 1);
        let wid = choose_soft_evict_worker(&p, fid(0), PlacementPolicy::Even).unwrap();
        assert_eq!(wid, WorkerId(0));
        p.get_mut(wid).sandboxes.soft_evict_one(fid(0)).unwrap();
        let alloc = choose_allocation_worker(&p, fid(0), 128, PlacementPolicy::Even);
        assert_eq!(alloc, Some(WorkerId(1)));
    }
}
