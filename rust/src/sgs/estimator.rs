//! Sandbox demand estimation (§4.3.1, Fig 5).
//!
//! Per DAG, the SGS records the request arrival count over each 100 ms
//! interval, smooths the measured rate with an EWMA, models arrivals in
//! the next interval as Poisson(λ̂·T), and provisions for the SLA
//! quantile via the exact inverse CDF. Functions whose execution time
//! exceeds the interval carry requests over into subsequent intervals, so
//! the demand is scaled by `ceil(exec / T)`.
//!
//! The estimator also maintains the per-DAG *queuing delay* EWMA + window
//! that the SGS piggybacks to the LBS as the universal scaling signal
//! (§5.2.1).

use std::collections::HashMap;

use crate::config::Micros;
use crate::dag::DagId;
use crate::util::rng::poisson_inv_cdf;
use crate::util::stats::{Ewma, Window};

/// Per-DAG arrival-rate estimator state.
#[derive(Debug)]
struct DagEstimate {
    /// Requests observed in the current (open) interval.
    interval_count: u64,
    /// Smoothed arrivals-per-interval.
    rate: Ewma,
    /// Smoothed queuing delay (µs).
    qdelay: Ewma,
    /// Queuing-delay observation window gating LBS decisions.
    qdelay_window: Window,
}

/// The SGS estimator module (Fig 4a).
#[derive(Debug)]
pub struct Estimator {
    interval: Micros,
    rate_alpha: f64,
    qdelay_alpha: f64,
    qdelay_window: usize,
    sla: f64,
    margin: f64,
    dags: HashMap<DagId, DagEstimate>,
}

/// A point-in-time demand snapshot for one DAG.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandReport {
    /// Smoothed arrivals per estimation interval.
    pub rate_per_interval: f64,
    /// SLA-quantile arrivals in one interval (before overflow scaling).
    pub base_demand: u64,
}

impl Estimator {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        interval: Micros,
        rate_alpha: f64,
        qdelay_alpha: f64,
        qdelay_window: usize,
        sla: f64,
        margin: f64,
    ) -> Self {
        Estimator {
            interval,
            rate_alpha,
            qdelay_alpha,
            qdelay_window,
            sla,
            margin,
            dags: HashMap::new(),
        }
    }

    pub fn interval(&self) -> Micros {
        self.interval
    }

    fn entry(&mut self, dag: DagId) -> &mut DagEstimate {
        let (ra, qa, qw) = (self.rate_alpha, self.qdelay_alpha, self.qdelay_window);
        self.dags.entry(dag).or_insert_with(|| DagEstimate {
            interval_count: 0,
            rate: Ewma::new(ra),
            qdelay: Ewma::new(qa),
            qdelay_window: Window::new(qw),
        })
    }

    /// Record one request arrival for `dag` (called on SGS enqueue of the
    /// DAG's roots — one count per DAG request).
    pub fn record_arrival(&mut self, dag: DagId) {
        self.entry(dag).interval_count += 1;
    }

    /// Record a queuing-delay observation (µs) for `dag`.
    pub fn record_qdelay(&mut self, dag: DagId, delay: Micros) {
        let e = self.entry(dag);
        e.qdelay.observe(delay as f64);
        e.qdelay_window.observe(delay as f64);
    }

    /// Close the current interval for every DAG: fold the interval count
    /// into the EWMA rate. Returns the per-DAG demand snapshots.
    pub fn tick(&mut self) -> Vec<(DagId, DemandReport)> {
        let sla = self.sla;
        let mut out: Vec<(DagId, DemandReport)> = self
            .dags
            .iter_mut()
            .map(|(dag, e)| {
                let measured = e.interval_count as f64;
                e.interval_count = 0;
                let rate = e.rate.observe(measured);
                let base = poisson_inv_cdf(sla, rate);
                (
                    *dag,
                    DemandReport {
                        rate_per_interval: rate,
                        base_demand: base,
                    },
                )
            })
            .collect();
        out.sort_by_key(|(d, _)| *d); // deterministic iteration order
        out
    }

    /// Demand for a specific function: the base (per-interval) demand
    /// scaled by the overflow factor for executions longer than T, plus
    /// the worst-case provisioning margin (§4.3.1 / Fig 8b).
    pub fn function_demand(&self, report: &DemandReport, exec_time: Micros) -> u32 {
        let overflow = exec_time.div_ceil(self.interval).max(1);
        let base = report.base_demand.saturating_mul(overflow);
        if base == 0 {
            return 0;
        }
        let with_margin = (base as f64 * (1.0 + self.margin)).ceil() as u64 + 1;
        u32::try_from(with_margin).unwrap_or(u32::MAX)
    }

    /// Smoothed queuing delay (µs) for a DAG, if observed.
    pub fn qdelay(&self, dag: DagId) -> Option<f64> {
        self.dags.get(&dag).and_then(|e| e.qdelay.get())
    }

    /// Is the queuing-delay window full (LBS may act on it)? §5.2.2:
    /// the LBS "makes the next scaling decision only once the windows are
    /// filled up to avoid reacting to transient changes".
    pub fn qdelay_window_full(&self, dag: DagId) -> bool {
        self.dags
            .get(&dag)
            .map(|e| e.qdelay_window.is_full())
            .unwrap_or(false)
    }

    /// Reset the queuing-delay window after an LBS scaling action so the
    /// next decision observes post-action behaviour (§5.2.2).
    pub fn reset_qdelay_window(&mut self, dag: DagId) {
        if let Some(e) = self.dags.get_mut(&dag) {
            e.qdelay_window.reset();
        }
    }

    /// Seed the rate estimate for a DAG this SGS has just been assigned
    /// (scale-out priming, §5.2.3) so the first estimator tick doesn't
    /// collapse the primed allocation back to zero.
    pub fn seed_rate(&mut self, dag: DagId, rate_per_interval: f64) {
        let e = self.entry(dag);
        if e.rate.get().is_none() {
            e.rate.observe(rate_per_interval.max(0.0));
        }
    }

    /// Stop tracking a DAG (it scaled away from this SGS entirely).
    pub fn forget(&mut self, dag: DagId) {
        self.dags.remove(&dag);
    }

    /// DAGs currently tracked.
    pub fn tracked(&self) -> Vec<DagId> {
        let mut v: Vec<DagId> = self.dags.keys().copied().collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn est() -> Estimator {
        Estimator::new(100 * MS, 0.3, 0.3, 4, 0.99, 0.0)
    }

    #[test]
    fn constant_rate_converges_and_demand_covers_sla() {
        let mut e = est();
        let dag = DagId(0);
        // 50 arrivals per interval, steady
        let mut last = DemandReport {
            rate_per_interval: 0.0,
            base_demand: 0,
        };
        for _ in 0..60 {
            for _ in 0..50 {
                e.record_arrival(dag);
            }
            let reports = e.tick();
            last = reports[0].1;
        }
        assert!((last.rate_per_interval - 50.0).abs() < 0.5);
        // Poisson(50) 99th percentile is ~67
        assert!(last.base_demand >= 60 && last.base_demand <= 75,
            "demand {}", last.base_demand);
    }

    #[test]
    fn demand_scales_with_execution_overflow() {
        let mut e = est();
        let dag = DagId(0);
        for _ in 0..20 {
            for _ in 0..10 {
                e.record_arrival(dag);
            }
            e.tick();
        }
        for _ in 0..10 {
            e.record_arrival(dag);
        }
        let reports = e.tick();
        let r = &reports[0].1;
        let d_short = e.function_demand(r, 50 * MS); // exec < T: no scale
        let d_exact = e.function_demand(r, 100 * MS); // exec == T: x1
        let d_long = e.function_demand(r, 250 * MS); // exec 2.5T: x3
        // margin 0 ⇒ demand = overflow·base + 1 (the +1 keeps at least
        // one spare sandbox even at tiny rates)
        assert_eq!(d_short, r.base_demand as u32 + 1);
        assert_eq!(d_exact, r.base_demand as u32 + 1);
        assert_eq!(d_long, 3 * r.base_demand as u32 + 1);
    }

    #[test]
    fn rate_decays_when_arrivals_stop() {
        let mut e = est();
        let dag = DagId(0);
        for _ in 0..30 {
            for _ in 0..100 {
                e.record_arrival(dag);
            }
            e.tick();
        }
        let high = e.tick();
        for _ in 0..40 {
            e.tick(); // silence
        }
        let low = e.tick();
        assert!(low[0].1.rate_per_interval < high[0].1.rate_per_interval / 10.0);
        assert!(low[0].1.base_demand < high[0].1.base_demand);
    }

    #[test]
    fn qdelay_window_gates_and_resets() {
        let mut e = est();
        let dag = DagId(0);
        assert!(!e.qdelay_window_full(dag));
        for i in 0..4 {
            assert!(!e.qdelay_window_full(dag), "at {i}");
            e.record_qdelay(dag, 1000);
        }
        assert!(e.qdelay_window_full(dag));
        assert!(e.qdelay(dag).unwrap() > 0.0);
        e.reset_qdelay_window(dag);
        assert!(!e.qdelay_window_full(dag));
        // EWMA survives the window reset
        assert!(e.qdelay(dag).is_some());
    }

    #[test]
    fn tick_is_deterministically_ordered() {
        let mut e = est();
        for d in [3u32, 1, 2, 0] {
            e.record_arrival(DagId(d));
        }
        let reports = e.tick();
        let ids: Vec<u32> = reports.iter().map(|(d, _)| d.0).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }

    #[test]
    fn forget_removes_state() {
        let mut e = est();
        e.record_arrival(DagId(0));
        e.record_qdelay(DagId(0), 5);
        e.forget(DagId(0));
        assert!(e.qdelay(DagId(0)).is_none());
        assert!(e.tracked().is_empty());
    }

    #[test]
    fn zero_rate_zero_demand() {
        let mut e = est();
        e.record_arrival(DagId(0));
        e.tick(); // rate > 0
        for _ in 0..200 {
            e.tick();
        }
        let r = e.tick();
        // decayed to ~0 → demand 0 or tiny
        assert!(r[0].1.base_demand <= 1);
    }
}
