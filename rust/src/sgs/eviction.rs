//! Hard-eviction victim selection (§4.3.3, Pseudocode 1 lines 39–46).
//!
//! When a worker's proactive memory pool is saturated and a new sandbox
//! must be placed there, the SGS evicts resident sandboxes until the new
//! one fits. Archipelago's **fair** policy victimizes the function whose
//! current allocation is closest to (or most above) its demand estimate —
//! functions already far *below* their estimate are protected. Soft-
//! evicted sandboxes are always preferred over warm ones within the
//! chosen function (handled by `SandboxTable::hard_evict_one`).
//!
//! The **LRU** ablation (§7.3.1) victimizes the least-recently-used
//! function's sandboxes; the paper measures it 4.62× worse on tail
//! latency because an off-period DAG loses all its sandboxes right before
//! its next on-period.

use std::collections::HashMap;

use crate::config::EvictionPolicy;
use crate::dag::FnId;
use crate::worker::Worker;

/// Pick the next victim function on `worker` for hard eviction, given
/// per-function demand estimates. `protect` is the function we are
/// making room for (never victimized).
pub fn choose_victim(
    worker: &Worker,
    estimates: &HashMap<FnId, u32>,
    protect: FnId,
    policy: EvictionPolicy,
) -> Option<FnId> {
    match policy {
        EvictionPolicy::Fair => {
            // Only functions allocated *above* their estimate are
            // candidates ("prevents functions whose allocations are far
            // from their estimation being negatively impacted" — an
            // under-provisioned function is never victimized; if no
            // function has surplus, the eviction fails and the caller
            // queues instead). Highest surplus loses first; soft-evicted
            // count (excess by definition) is included in "allocated".
            let mut best: Option<(i64, FnId)> = None;
            for (f, evictable, _mem, _lu, soft) in worker.sandboxes.evictable() {
                if f == protect || evictable == 0 {
                    continue;
                }
                let active = worker.sandboxes.active(f);
                let allocated = (active + soft) as i64;
                let estimated = *estimates.get(&f).unwrap_or(&0) as i64;
                let surplus = allocated - estimated;
                if surplus <= 0 {
                    continue; // protected: at or below its estimate
                }
                let better = match best {
                    None => true,
                    Some((s, bf)) => surplus > s || (surplus == s && f < bf),
                };
                if better {
                    best = Some((surplus, f));
                }
            }
            best.map(|(_, f)| f)
        }
        EvictionPolicy::Lru => {
            let mut best: Option<(u64, FnId)> = None;
            for (f, evictable, _mem, last_used, _soft) in worker.sandboxes.evictable() {
                if f == protect || evictable == 0 {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((lu, bf)) => last_used < lu || (last_used == lu && f < bf),
                };
                if better {
                    best = Some((last_used, f));
                }
            }
            best.map(|(_, f)| f)
        }
    }
}

/// Evict sandboxes on `worker` until `need_mb` of pool memory is free.
/// Returns the number of sandboxes evicted, or `None` if the space
/// cannot be freed (everything else is busy).
pub fn evict_until_fits(
    worker: &mut Worker,
    estimates: &HashMap<FnId, u32>,
    protect: FnId,
    need_mb: u64,
    policy: EvictionPolicy,
) -> Option<u32> {
    let mut evicted = 0;
    while worker.sandboxes.pool_free_mb() < need_mb {
        let victim = choose_victim(worker, estimates, protect, policy)?;
        worker
            .sandboxes
            .hard_evict_one(victim)
            .expect("victim came from evictable()");
        evicted += 1;
    }
    Some(evicted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagId;
    use crate::worker::WorkerId;

    fn fid(i: u16) -> FnId {
        FnId {
            dag: DagId(0),
            idx: i,
        }
    }

    fn worker_with(pool_mb: u64) -> Worker {
        Worker::new(WorkerId(0), 4, pool_mb)
    }

    fn add_warm(w: &mut Worker, f: FnId, n: u32, last_used: u64) {
        for _ in 0..n {
            w.sandboxes.begin_setup(f, 128).unwrap();
            w.sandboxes.finish_setup(f).unwrap();
        }
        if n > 0 {
            w.sandboxes.acquire_warm(f, last_used).unwrap();
            w.sandboxes.release(f, last_used).unwrap();
        }
    }

    #[test]
    fn fair_evicts_most_overprovisioned() {
        let mut w = worker_with(4096);
        add_warm(&mut w, fid(0), 4, 10); // estimate 1 → surplus 3
        add_warm(&mut w, fid(1), 2, 5); // estimate 4 → surplus -2 (protected-ish)
        let est = HashMap::from([(fid(0), 1u32), (fid(1), 4u32)]);
        let v = choose_victim(&w, &est, fid(9), EvictionPolicy::Fair);
        assert_eq!(v, Some(fid(0)));
    }

    #[test]
    fn fair_treats_missing_estimate_as_zero() {
        let mut w = worker_with(4096);
        add_warm(&mut w, fid(0), 1, 10); // no estimate → surplus 1
        add_warm(&mut w, fid(1), 2, 5); // estimate 5 → surplus -3
        let est = HashMap::from([(fid(1), 5u32)]);
        assert_eq!(
            choose_victim(&w, &est, fid(9), EvictionPolicy::Fair),
            Some(fid(0))
        );
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut w = worker_with(4096);
        add_warm(&mut w, fid(0), 2, 100);
        add_warm(&mut w, fid(1), 2, 50); // older
        let est = HashMap::new();
        assert_eq!(
            choose_victim(&w, &est, fid(9), EvictionPolicy::Lru),
            Some(fid(1))
        );
    }

    #[test]
    fn protected_function_never_victim() {
        let mut w = worker_with(4096);
        add_warm(&mut w, fid(0), 3, 1);
        let est = HashMap::new();
        assert_eq!(choose_victim(&w, &est, fid(0), EvictionPolicy::Fair), None);
        assert_eq!(choose_victim(&w, &est, fid(0), EvictionPolicy::Lru), None);
    }

    #[test]
    fn evict_until_fits_frees_enough() {
        let mut w = worker_with(512); // 4 × 128
        add_warm(&mut w, fid(0), 2, 10);
        add_warm(&mut w, fid(1), 2, 20);
        assert_eq!(w.sandboxes.pool_free_mb(), 0);
        let est = HashMap::from([(fid(0), 0u32), (fid(1), 2u32)]);
        let n = evict_until_fits(&mut w, &est, fid(2), 256, EvictionPolicy::Fair)
            .unwrap();
        assert_eq!(n, 2);
        assert!(w.sandboxes.pool_free_mb() >= 256);
        // fair policy drained the over-provisioned fid(0) first
        assert_eq!(w.sandboxes.active(fid(0)), 0);
        assert_eq!(w.sandboxes.active(fid(1)), 2);
    }

    #[test]
    fn evict_until_fits_fails_when_everything_busy() {
        let mut w = worker_with(256);
        w.sandboxes.acquire_cold(fid(0), 128, 0).unwrap();
        w.sandboxes.acquire_cold(fid(1), 128, 0).unwrap();
        let est = HashMap::new();
        assert_eq!(
            evict_until_fits(&mut w, &est, fid(2), 128, EvictionPolicy::Fair),
            None
        );
    }

    #[test]
    fn evict_noop_when_space_already_free() {
        let mut w = worker_with(1024);
        add_warm(&mut w, fid(0), 1, 0);
        let est = HashMap::new();
        assert_eq!(
            evict_until_fits(&mut w, &est, fid(1), 128, EvictionPolicy::Fair),
            Some(0)
        );
        assert_eq!(w.sandboxes.active(fid(0)), 1, "nothing evicted");
    }
}
