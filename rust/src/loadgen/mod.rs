//! Open-loop load generation against the wall-clock server — the
//! harness that measures the paper's headline claim (">99% of requests
//! meet their deadline", §7.2) end-to-end instead of in simulation.
//!
//! **Open-loop contract:** the replayer walks a pre-materialized
//! schedule ([`crate::workload::schedule`]) and dispatches each request
//! at its scheduled wall-clock time via the non-blocking
//! [`Server::submit_dag_async`], *never* waiting for completions — so
//! offered load is independent of how the platform is doing, exactly
//! like real user traffic. When the generator falls behind (dispatch
//! overhead exceeds an arrival gap), the lag is **recorded, not
//! absorbed**: the request is sent immediately and counted in
//! `late_dispatches`/`max_dispatch_lag_us`, the way serious open-loop
//! harnesses (wrk2, Lancet) treat coordinated omission. Completions
//! flow into the server's shared [`crate::metrics::Metrics`] shards;
//! the run report
//! reads them back (deadline attainment, p50/p99/p99.9, queue delays,
//! cold starts) and reconciles them against the sink's own tallies.
//!
//! A run on a fresh server measures exactly this schedule; reusing a
//! server accumulates into its metrics (the report would mix runs).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::{Micros, SchedPolicy, SEC};
use crate::dag::{DagId, DagSpec, FunctionSpec};
use crate::metrics::fmt_us;
use crate::platform::realtime::{CompletionSink, RequestResult, RtOptions, Server};
use crate::runtime::{Manifest, RuntimeError, StubExecutorFactory};
use crate::util::json::{self, Json};
use crate::util::stats::LogHistogram;
use crate::workload::schedule::{materialize_schedule, scale_us};
use crate::workload::{macro_mix, offered_cores, App, WorkloadKind};

/// The sink shared by every in-flight request of a run: lock-free
/// result counters plus a mutex'd histogram of per-function cold-start
/// (setup) times. Completions arrive on worker threads; one `Arc` of
/// this serves the whole run.
#[derive(Default)]
pub struct OpenLoopSink {
    done: AtomicU64,
    failed: AtomicU64,
    met: AtomicU64,
    setup: Mutex<LogHistogram>,
}

impl OpenLoopSink {
    /// Requests with a successful terminal result.
    pub fn done(&self) -> u64 {
        self.done.load(Ordering::Relaxed)
    }

    /// Requests with an explicit failed completion.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    /// Successful requests that met their deadline.
    pub fn met(&self) -> u64 {
        self.met.load(Ordering::Relaxed)
    }

    /// Terminal results delivered so far (done + failed).
    pub fn settled(&self) -> u64 {
        self.done() + self.failed()
    }
}

impl CompletionSink for OpenLoopSink {
    fn complete(&self, result: RequestResult) {
        match result {
            RequestResult::Done(c) => {
                self.done.fetch_add(1, Ordering::Relaxed);
                if c.deadline_met {
                    self.met.fetch_add(1, Ordering::Relaxed);
                }
                let mut h = self.setup.lock().unwrap();
                for f in &c.functions {
                    if f.setup_us > 0 {
                        h.record(f.setup_us);
                    }
                }
            }
            RequestResult::Failed(_) => {
                self.failed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Canonical short label for a scheduling policy (report rows, CLI).
pub fn policy_label(policy: SchedPolicy) -> &'static str {
    match policy {
        SchedPolicy::Srsf => "srsf",
        SchedPolicy::Fifo => "fifo",
    }
}

/// Replay knobs (the schedule itself carries the arrival pattern).
#[derive(Debug, Clone)]
pub struct LoadgenOptions {
    /// How long to wait for stragglers after the last dispatch before
    /// reporting. Requests still unsettled then are reported as such —
    /// never silently dropped.
    pub drain: Duration,
    /// Dispatch lag beyond this is counted as late (sleep granularity
    /// makes a few tens of µs of lag unavoidable noise).
    pub late_threshold_us: u64,
}

impl Default for LoadgenOptions {
    fn default() -> Self {
        LoadgenOptions {
            drain: Duration::from_secs(30),
            late_threshold_us: 1_000,
        }
    }
}

/// One run's report: the paper's attainment/latency quantities read
/// from the shared [`crate::metrics::Metrics`], reconciled with the
/// open-loop sink's tallies and the dispatcher's lag accounting.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Caller label, e.g. the scheduling policy under test.
    pub label: String,
    pub submitted: u64,
    /// Schedule entries the server refused at admission (unknown DAG).
    pub rejected: u64,
    pub done: u64,
    pub failed: u64,
    /// Submitted but no terminal result within the drain window.
    pub unsettled: u64,
    /// Lifecycle completions per the server's metrics.
    pub completed: u64,
    /// Deadline-attainment fraction (failed requests count against it).
    pub attainment: f64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub qdelay_p50_us: u64,
    pub qdelay_p99_us: u64,
    pub setup_p50_us: u64,
    pub setup_p99_us: u64,
    pub cold_starts: u64,
    /// Completion throughput over the whole run (done / wall).
    pub rps: f64,
    /// What the schedule asked for (entries / schedule span).
    pub offered_rps: f64,
    pub late_dispatches: u64,
    pub max_dispatch_lag_us: u64,
    pub wall_secs: f64,
}

impl LoadReport {
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("submitted", Json::Int(self.submitted as i64)),
            ("rejected", Json::Int(self.rejected as i64)),
            ("done", Json::Int(self.done as i64)),
            ("failed", Json::Int(self.failed as i64)),
            ("unsettled", Json::Int(self.unsettled as i64)),
            ("completed", Json::Int(self.completed as i64)),
            ("deadline_attainment", Json::Num(self.attainment)),
            ("p50_us", Json::Int(self.p50_us as i64)),
            ("p99_us", Json::Int(self.p99_us as i64)),
            ("p999_us", Json::Int(self.p999_us as i64)),
            ("max_us", Json::Int(self.max_us as i64)),
            ("qdelay_p50_us", Json::Int(self.qdelay_p50_us as i64)),
            ("qdelay_p99_us", Json::Int(self.qdelay_p99_us as i64)),
            ("setup_p50_us", Json::Int(self.setup_p50_us as i64)),
            ("setup_p99_us", Json::Int(self.setup_p99_us as i64)),
            ("cold_starts", Json::Int(self.cold_starts as i64)),
            ("requests_per_sec", Json::Num(self.rps)),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("late_dispatches", Json::Int(self.late_dispatches as i64)),
            (
                "max_dispatch_lag_us",
                Json::Int(self.max_dispatch_lag_us as i64),
            ),
            ("wall_secs", Json::Num(self.wall_secs)),
        ])
    }

    /// Two-line human report (CLI + bench output).
    pub fn format(&self) -> String {
        format!(
            "[{}] submitted={} done={} failed={} unsettled={} rejected={} \
             late={} (max lag {})\n  attainment={:.2}%  p50={} p99={} p99.9={}  \
             qdelay p99={}  cold={}  {:.1} req/s (offered {:.1}) over {:.1}s",
            self.label,
            self.submitted,
            self.done,
            self.failed,
            self.unsettled,
            self.rejected,
            self.late_dispatches,
            fmt_us(self.max_dispatch_lag_us),
            self.attainment * 100.0,
            fmt_us(self.p50_us),
            fmt_us(self.p99_us),
            fmt_us(self.p999_us),
            fmt_us(self.qdelay_p99_us),
            self.cold_starts,
            self.rps,
            self.offered_rps,
            self.wall_secs,
        )
    }
}

/// Replay `schedule` against `server`, open-loop, and report.
///
/// Dispatches from the calling thread; completions are accounted on the
/// server's worker threads through one shared [`OpenLoopSink`]. Run
/// this against a *fresh* server — the report reads the server's
/// cumulative metrics. Deadlines are each DAG's registered default
/// ([`Server::dag_deadline`]); a time-scaled replay should register
/// time-scaled specs (see [`prepare_stub`]) so estimates, service
/// times, and deadlines stay self-similar.
pub fn run(
    server: &Server,
    schedule: &[(Micros, DagId)],
    label: &str,
    opts: &LoadgenOptions,
) -> LoadReport {
    let sink = Arc::new(OpenLoopSink::default());
    let mut deadlines: HashMap<u32, Micros> = HashMap::new();
    let mut submitted = 0u64;
    let mut rejected = 0u64;
    let mut late = 0u64;
    let mut max_lag = 0u64;
    let t0 = Instant::now();
    for &(t, dag) in schedule {
        let now_us = t0.elapsed().as_micros() as u64;
        if now_us < t {
            std::thread::sleep(Duration::from_micros(t - now_us));
        } else {
            let lag = now_us - t;
            if lag > opts.late_threshold_us {
                late += 1;
            }
            max_lag = max_lag.max(lag);
        }
        let deadline = match deadlines.entry(dag.0) {
            std::collections::hash_map::Entry::Occupied(e) => Some(*e.get()),
            std::collections::hash_map::Entry::Vacant(e) => {
                server.dag_deadline(dag).map(|d| *e.insert(d))
            }
        };
        let sink: Arc<dyn CompletionSink> = sink.clone();
        let admitted =
            deadline.and_then(|d| server.submit_dag_async(dag, vec![1.0], d, sink));
        match admitted {
            Some(_) => submitted += 1,
            None => rejected += 1,
        }
    }
    // Open loop: dispatching never waited; stragglers get a bounded
    // drain window now, and whatever is still unsettled is reported.
    let drain_deadline = Instant::now() + opts.drain;
    while sink.settled() < submitted && Instant::now() < drain_deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    let wall = t0.elapsed().as_secs_f64();
    // Snapshot the sink once: completions may still be arriving after a
    // drain timeout, and the report's identity (done + failed +
    // unsettled == submitted) must hold over one consistent view. The
    // metrics row is read after the snapshot, so `completed >= done`
    // (each sink delivery happens after its metrics record).
    let done_n = sink.done();
    let failed_n = sink.failed();
    let row = server.summary();
    let (setup_p50, setup_p99) = {
        let h = sink.setup.lock().unwrap();
        (h.quantile(0.5), h.quantile(0.99))
    };
    let span_us = schedule.last().map(|&(t, _)| t).unwrap_or(0).max(1);
    LoadReport {
        label: label.to_string(),
        submitted,
        rejected,
        done: done_n,
        failed: failed_n,
        unsettled: submitted - (done_n + failed_n),
        completed: row.completed,
        attainment: row.deadline_met_rate,
        p50_us: row.p50,
        p99_us: row.p99,
        p999_us: row.p999,
        max_us: row.max,
        qdelay_p50_us: row.qdelay_p50,
        qdelay_p99_us: row.qdelay_p99,
        setup_p50_us: setup_p50,
        setup_p99_us: setup_p99,
        cold_starts: row.cold_starts,
        rps: done_n as f64 / wall.max(1e-9),
        offered_rps: schedule.len() as f64 * SEC as f64 / span_us as f64,
        late_dispatches: late,
        max_dispatch_lag_us: max_lag,
        wall_secs: wall,
    }
}

// ---------------------------------------------------------------------
// Stub replay preparation: a macro-mix workload sized to a stub cluster
// so `archipelago loadtest --stub` and `benches/e2e.rs` share one
// construction path.
// ---------------------------------------------------------------------

/// Configuration for a stub-executor loadtest.
#[derive(Debug, Clone)]
pub struct StubLoadtestConfig {
    pub kind: WorkloadKind,
    pub policy: SchedPolicy,
    /// Coordinator shards.
    pub num_sgs: usize,
    /// Worker threads per shard (one core each).
    pub workers: usize,
    /// Schedule horizon in *virtual* seconds (pre-scale).
    pub duration_s: u64,
    /// Stretch factor for the whole run: arrivals, service times, and
    /// deadlines (2.0 = the same workload in half-speed slow motion).
    pub time_scale: f64,
    /// Target mean utilization of the stub cluster's cores; the W1/W2
    /// rates are scaled to hit it (sinusoid peaks still overshoot —
    /// that transient overload is what SRSF earns its keep on).
    pub util: f64,
    pub dags_per_class: usize,
    pub seed: u64,
    /// Run the estimator/LBS control loops (proactive allocation).
    pub background_ticks: bool,
}

impl Default for StubLoadtestConfig {
    fn default() -> Self {
        StubLoadtestConfig {
            kind: WorkloadKind::W2,
            policy: SchedPolicy::Srsf,
            num_sgs: 2,
            workers: 2,
            duration_s: 15,
            time_scale: 1.0,
            util: 0.8,
            dags_per_class: 1,
            seed: 42,
            background_ticks: true,
        }
    }
}

/// Rebuild a spec with exec/setup/deadline stretched by `s`, so the
/// scheduler's estimates, the stub's service times, and the deadline
/// all live on the same (scaled) clock.
fn scale_spec(spec: &DagSpec, s: f64) -> DagSpec {
    let functions: Vec<FunctionSpec> = spec
        .functions
        .iter()
        .map(|f| {
            FunctionSpec::new(
                &f.name,
                scale_us(f.exec_time, s).max(1),
                scale_us(f.setup_time, s),
                f.mem_mb,
            )
        })
        .collect();
    DagSpec::new(
        spec.id,
        &spec.name,
        functions,
        spec.edges.clone(),
        scale_us(spec.deadline, s).max(1),
    )
    .expect("scaling preserves DAG validity")
}

/// Per-artifact stub service costs for the (already scaled) specs:
/// every function gets its own sampled setup/exec time instead of a
/// flat constant, so the stub cluster reproduces the workload's
/// service-time distribution.
pub fn stub_costs(dags: &[DagSpec]) -> HashMap<String, (Duration, Duration)> {
    let mut m = HashMap::new();
    for dag in dags {
        for f in &dag.functions {
            m.insert(
                f.name.clone(),
                (
                    Duration::from_micros(f.setup_time),
                    Duration::from_micros(f.exec_time),
                ),
            );
        }
    }
    m
}

/// Build the stub server + schedule for `cfg`: a C1–C4 macro mix whose
/// mean offered load is fitted to `util × (num_sgs × workers)` cores,
/// materialized over `duration_s` and stretched by `time_scale`. The
/// same `(kind, dags_per_class, seed)` always yields the same mix and
/// schedule, so two policies compared with this function replay
/// identical traffic.
pub fn prepare_stub(
    cfg: &StubLoadtestConfig,
) -> Result<(Server, Vec<(Micros, DagId)>), RuntimeError> {
    // Fit the mix's mean offered cores to the stub capacity.
    let probe = macro_mix(cfg.kind, cfg.dags_per_class, 1.0, cfg.seed);
    let mean_offered: f64 = probe.iter().map(offered_cores).sum();
    let capacity = (cfg.num_sgs * cfg.workers) as f64;
    let rate_scale = cfg.util * capacity / mean_offered.max(1e-9);
    let apps: Vec<App> = macro_mix(cfg.kind, cfg.dags_per_class, rate_scale, cfg.seed);

    let schedule = materialize_schedule(&apps, cfg.duration_s * SEC, cfg.time_scale, cfg.seed);

    let dags: Vec<DagSpec> = apps
        .iter()
        .map(|a| scale_spec(&a.dag, cfg.time_scale))
        .collect();
    let factory = Arc::new(StubExecutorFactory {
        costs: stub_costs(&dags),
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs: cfg.num_sgs,
        workers: cfg.workers,
        policy: cfg.policy,
        background_ticks: cfg.background_ticks,
        pool_mb: 8 * 1024,
    };
    let server = Server::start_with(factory, dags, opts, &[], Manifest::empty())?;
    Ok((server, schedule))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    #[test]
    fn scale_spec_stretches_times_and_deadline() {
        let dag = DagSpec::chain(
            DagId(0),
            "c",
            &[(10 * MS, 100 * MS, 128), (20 * MS, 100 * MS, 128)],
            400 * MS,
        );
        let scaled = scale_spec(&dag, 2.0);
        assert_eq!(scaled.functions[0].exec_time, 20 * MS);
        assert_eq!(scaled.functions[1].exec_time, 40 * MS);
        assert_eq!(scaled.functions[0].setup_time, 200 * MS);
        assert_eq!(scaled.deadline, 800 * MS);
        assert_eq!(scaled.edges, dag.edges);
        let costs = stub_costs(&[scaled]);
        assert_eq!(
            costs["c-s0"],
            (Duration::from_millis(200), Duration::from_millis(20))
        );
    }

    #[test]
    fn prepare_stub_fits_offered_load_and_is_deterministic() {
        let cfg = StubLoadtestConfig {
            duration_s: 5,
            background_ticks: false,
            ..Default::default()
        };
        let (server, schedule) = prepare_stub(&cfg).unwrap();
        let (server2, schedule2) = prepare_stub(&cfg).unwrap();
        assert_eq!(schedule, schedule2, "same cfg, same schedule");
        assert!(!schedule.is_empty());
        // mean offered rate ≈ util × capacity / mean exec: just sanity-
        // check the schedule is neither empty nor absurdly dense.
        let rps = schedule.len() as f64 / cfg.duration_s as f64;
        assert!(rps > 1.0 && rps < 500.0, "offered {rps} rps");
        server.shutdown();
        server2.shutdown();
    }

    #[test]
    fn open_loop_run_settles_and_reconciles() {
        let cfg = StubLoadtestConfig {
            duration_s: 2,
            time_scale: 0.2, // 5× fast-forward: ~0.4 s of wall dispatch
            util: 0.5,
            background_ticks: false,
            ..Default::default()
        };
        let (server, schedule) = prepare_stub(&cfg).unwrap();
        let report = run(&server, &schedule, "unit", &LoadgenOptions::default());
        assert_eq!(report.submitted, schedule.len() as u64);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.unsettled, 0, "drain must settle everything");
        assert_eq!(report.done + report.failed, report.submitted);
        assert_eq!(report.failed, 0);
        assert_eq!(
            report.completed, report.done,
            "metrics and sink must agree on completions"
        );
        assert!(report.attainment >= 0.0 && report.attainment <= 1.0);
        assert!(report.rps > 0.0);
        // report serializes
        let j = report.to_json();
        assert_eq!(
            j.get("submitted").unwrap().as_u64(),
            Some(report.submitted)
        );
        assert!(report.format().contains("attainment="));
        server.shutdown();
    }
}
