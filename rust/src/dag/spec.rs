//! The JSON-based DAG upload language (§3: "the user also specifies the
//! resource requirements of the functions along with the DAG structure
//! using a JSON-based language ... [and] the maximum execution time for
//! the DAG given a new input trigger").
//!
//! Example document:
//!
//! ```json
//! {
//!   "name": "thumbnailer",
//!   "deadline_us": 150000,
//!   "functions": [
//!     {"name": "resize", "exec_time_us": 50000, "setup_time_us": 200000,
//!      "mem_mb": 128, "artifact": "mlp_infer_b1"},
//!     {"name": "notify", "exec_time_us": 10000, "setup_time_us": 125000,
//!      "mem_mb": 128}
//!   ],
//!   "edges": [[0, 1]]
//! }
//! ```

use super::{DagError, DagId, DagSpec, FunctionSpec};
use crate::util::json;

#[derive(Debug)]
pub enum DagSpecError {
    Json(String),
    Structure(DagError),
}

impl std::fmt::Display for DagSpecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagSpecError::Json(m) => write!(f, "dag json: {m}"),
            DagSpecError::Structure(e) => write!(f, "dag structure: {e}"),
        }
    }
}

impl std::error::Error for DagSpecError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            DagSpecError::Structure(e) => Some(e),
            _ => None,
        }
    }
}

impl From<DagError> for DagSpecError {
    fn from(e: DagError) -> Self {
        DagSpecError::Structure(e)
    }
}

/// Parse + validate a DAG upload document.
pub fn parse_dag_json(id: DagId, text: &str) -> Result<DagSpec, DagSpecError> {
    let v = json::parse(text).map_err(|e| DagSpecError::Json(e.to_string()))?;
    let name = v.req_str("name").map_err(DagSpecError::Json)?;
    let deadline = v.req_u64("deadline_us").map_err(DagSpecError::Json)?;
    let fns_json = v
        .req("functions")
        .map_err(DagSpecError::Json)?
        .as_arr()
        .ok_or_else(|| DagSpecError::Json("'functions' must be an array".into()))?;
    let mut functions = Vec::with_capacity(fns_json.len());
    for (i, f) in fns_json.iter().enumerate() {
        let fname = f
            .req_str("name")
            .map_err(|e| DagSpecError::Json(format!("function[{i}]: {e}")))?;
        let exec = f
            .req_u64("exec_time_us")
            .map_err(|e| DagSpecError::Json(format!("function[{i}]: {e}")))?;
        let setup = f
            .req_u64("setup_time_us")
            .map_err(|e| DagSpecError::Json(format!("function[{i}]: {e}")))?;
        let mem = f
            .req_u64("mem_mb")
            .map_err(|e| DagSpecError::Json(format!("function[{i}]: {e}")))?;
        let artifact = f
            .get("artifact")
            .and_then(|a| a.as_str())
            .unwrap_or("")
            .to_string();
        let mut spec = FunctionSpec::new(fname, exec, setup, mem);
        spec.artifact = artifact;
        functions.push(spec);
    }
    let mut edges = Vec::new();
    if let Some(arr) = v.get("edges").and_then(|e| e.as_arr()) {
        for (i, e) in arr.iter().enumerate() {
            let pair = e
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| {
                    DagSpecError::Json(format!("edges[{i}] must be a [parent, child] pair"))
                })?;
            let p = pair[0]
                .as_u64()
                .ok_or_else(|| DagSpecError::Json(format!("edges[{i}][0] must be an index")))?;
            let c = pair[1]
                .as_u64()
                .ok_or_else(|| DagSpecError::Json(format!("edges[{i}][1] must be an index")))?;
            let conv = |x: u64, what: &str| {
                u16::try_from(x)
                    .map_err(|_| DagSpecError::Json(format!("edges[{i}] {what} out of range")))
            };
            edges.push((conv(p, "parent")?, conv(c, "child")?));
        }
    }
    Ok(DagSpec::new(id, name, functions, edges, deadline)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"{
      "name": "thumbnailer",
      "deadline_us": 150000,
      "functions": [
        {"name": "resize", "exec_time_us": 50000, "setup_time_us": 200000,
         "mem_mb": 128, "artifact": "mlp_infer_b1"},
        {"name": "notify", "exec_time_us": 10000, "setup_time_us": 125000,
         "mem_mb": 128}
      ],
      "edges": [[0, 1]]
    }"#;

    #[test]
    fn parse_example_document() {
        let d = parse_dag_json(DagId(3), DOC).unwrap();
        assert_eq!(d.name, "thumbnailer");
        assert_eq!(d.deadline, 150_000);
        assert_eq!(d.functions[0].artifact, "mlp_infer_b1");
        assert_eq!(d.functions[1].artifact, "");
        assert_eq!(d.edges, vec![(0, 1)]);
        assert_eq!(d.total_cpl, 60_000);
        assert_eq!(d.id, DagId(3));
    }

    #[test]
    fn missing_fields_are_errors() {
        assert!(parse_dag_json(DagId(0), r#"{"name": "x"}"#).is_err());
        assert!(parse_dag_json(
            DagId(0),
            r#"{"name":"x","deadline_us":1,"functions":[{"name":"f"}]}"#
        )
        .is_err());
        assert!(parse_dag_json(DagId(0), "not json").is_err());
    }

    #[test]
    fn edges_optional() {
        let d = parse_dag_json(
            DagId(0),
            r#"{"name":"x","deadline_us":1000,
               "functions":[{"name":"f","exec_time_us":10,"setup_time_us":5,"mem_mb":128}]}"#,
        )
        .unwrap();
        assert!(d.edges.is_empty());
    }

    #[test]
    fn bad_edge_shapes_rejected() {
        let base = r#"{"name":"x","deadline_us":1000,
            "functions":[{"name":"a","exec_time_us":1,"setup_time_us":1,"mem_mb":1},
                         {"name":"b","exec_time_us":1,"setup_time_us":1,"mem_mb":1}],
            "edges": EDGES}"#;
        for bad in ["[[0]]", "[[0,1,2]]", "[\"x\"]", "[[0,\"b\"]]"] {
            let doc = base.replace("EDGES", bad);
            assert!(parse_dag_json(DagId(0), &doc).is_err(), "{bad}");
        }
        // cycle rejected through structural validation
        let doc = base.replace("EDGES", "[[0,1],[1,0]]");
        assert!(matches!(
            parse_dag_json(DagId(0), &doc).unwrap_err(),
            DagSpecError::Structure(DagError::Cyclic(_))
        ));
    }
}
