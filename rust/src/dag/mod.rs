//! The application model: a DAG of functions with a latency deadline (§3).
//!
//! Users upload a DAG spec (functions with resource requirements + edges +
//! the maximum acceptable end-to-end time); Archipelago schedules each
//! request's constituent functions so that the DAG completes within its
//! deadline. This module holds the spec types, the JSON upload language,
//! structural validation (acyclicity, connectivity), and the critical-path
//! precomputation the SRSF scheduler's slack calculation relies on (§4.2).

mod spec;

pub use spec::{parse_dag_json, DagSpecError};

use crate::config::Micros;
use crate::util::json::{self, Json};

/// Dense DAG identifier (index into the platform's registry).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DagId(pub u32);

/// A function *within* a DAG: `(dag, index)` — globally unique and dense,
/// used as the sandbox-table key everywhere.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FnId {
    pub dag: DagId,
    pub idx: u16,
}

/// One function node of a DAG.
#[derive(Debug, Clone)]
pub struct FunctionSpec {
    pub name: String,
    /// Provisioned memory (MB) — the sandbox's pool footprint (T4: 78%
    /// of real functions need only 128 MB).
    pub mem_mb: u64,
    /// Expected execution time, used for slack math. The generator may
    /// add per-request noise around this.
    pub exec_time: Micros,
    /// Sandbox setup overhead for this function (cold start cost):
    /// container launch + runtime + code fetch (§7.1: 125–400 ms).
    pub setup_time: Micros,
    /// Which compiled artifact runs this function in real-execution mode
    /// (name in `artifacts/manifest.json`); empty = simulated body.
    pub artifact: String,
}

impl FunctionSpec {
    pub fn new(name: &str, exec_time: Micros, setup_time: Micros, mem_mb: u64) -> Self {
        FunctionSpec {
            name: name.to_string(),
            mem_mb,
            exec_time,
            setup_time,
            artifact: String::new(),
        }
    }
}

/// A validated DAG with precomputed scheduling metadata.
#[derive(Debug, Clone)]
pub struct DagSpec {
    pub id: DagId,
    pub name: String,
    pub functions: Vec<FunctionSpec>,
    /// Edges as (parent, child) function indices.
    pub edges: Vec<(u16, u16)>,
    /// User-specified end-to-end deadline for a request (§3: "maximum
    /// execution time for the DAG given a new input trigger").
    pub deadline: Micros,

    // ---- precomputed ----
    /// Children per function.
    pub children: Vec<Vec<u16>>,
    /// Parent count per function (consumed as dependencies complete).
    pub parent_count: Vec<u16>,
    /// Root functions (no parents).
    pub roots: Vec<u16>,
    /// Critical-path execution time from each function to the DAG sink,
    /// *including* the function's own exec time (§4.2 "DAG awareness").
    pub cpl: Vec<Micros>,
    /// Critical-path execution time of the whole DAG.
    pub total_cpl: Micros,
    /// Topological order (parents before children).
    pub topo: Vec<u16>,
}

#[derive(Debug, PartialEq)]
pub enum DagError {
    Empty(String),
    BadEdge(String, u16),
    Cyclic(String),
    DuplicateEdge(String, u16, u16),
    SelfEdge(String, u16),
    ZeroDeadline(String),
}

impl std::fmt::Display for DagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagError::Empty(d) => write!(f, "dag '{d}' has no functions"),
            DagError::BadEdge(d, i) => {
                write!(f, "dag '{d}': edge references unknown function {i}")
            }
            DagError::Cyclic(d) => write!(f, "dag '{d}' contains a cycle"),
            DagError::DuplicateEdge(d, p, c) => {
                write!(f, "dag '{d}': duplicate edge ({p}, {c})")
            }
            DagError::SelfEdge(d, i) => write!(f, "dag '{d}': self edge on {i}"),
            DagError::ZeroDeadline(d) => write!(f, "dag '{d}': deadline must be > 0"),
        }
    }
}

impl std::error::Error for DagError {}

impl DagSpec {
    /// Build + validate a DAG, computing children/roots/critical paths.
    pub fn new(
        id: DagId,
        name: &str,
        functions: Vec<FunctionSpec>,
        edges: Vec<(u16, u16)>,
        deadline: Micros,
    ) -> Result<DagSpec, DagError> {
        let n = functions.len();
        if n == 0 {
            return Err(DagError::Empty(name.into()));
        }
        if deadline == 0 {
            return Err(DagError::ZeroDeadline(name.into()));
        }
        let mut children: Vec<Vec<u16>> = vec![Vec::new(); n];
        let mut parent_count: Vec<u16> = vec![0; n];
        let mut seen = std::collections::HashSet::new();
        for &(p, c) in &edges {
            if p as usize >= n {
                return Err(DagError::BadEdge(name.into(), p));
            }
            if c as usize >= n {
                return Err(DagError::BadEdge(name.into(), c));
            }
            if p == c {
                return Err(DagError::SelfEdge(name.into(), p));
            }
            if !seen.insert((p, c)) {
                return Err(DagError::DuplicateEdge(name.into(), p, c));
            }
            children[p as usize].push(c);
            parent_count[c as usize] += 1;
        }
        // Kahn topological sort — detects cycles.
        let mut indeg = parent_count.clone();
        let mut topo: Vec<u16> = Vec::with_capacity(n);
        let mut queue: std::collections::VecDeque<u16> = (0..n as u16)
            .filter(|&i| indeg[i as usize] == 0)
            .collect();
        let roots: Vec<u16> = queue.iter().copied().collect();
        while let Some(u) = queue.pop_front() {
            topo.push(u);
            for &v in &children[u as usize] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    queue.push_back(v);
                }
            }
        }
        if topo.len() != n {
            return Err(DagError::Cyclic(name.into()));
        }
        // Critical path to sink (reverse topological order), inclusive of
        // own exec time.
        let mut cpl: Vec<Micros> = vec![0; n];
        for &u in topo.iter().rev() {
            let below = children[u as usize]
                .iter()
                .map(|&v| cpl[v as usize])
                .max()
                .unwrap_or(0);
            cpl[u as usize] = functions[u as usize].exec_time + below;
        }
        let total_cpl = roots.iter().map(|&r| cpl[r as usize]).max().unwrap_or(0);
        Ok(DagSpec {
            id,
            name: name.to_string(),
            functions,
            edges,
            deadline,
            children,
            parent_count,
            roots,
            cpl,
            total_cpl,
            topo,
        })
    }

    /// Single-function convenience constructor (T5: the common case).
    pub fn single(
        id: DagId,
        name: &str,
        exec_time: Micros,
        setup_time: Micros,
        mem_mb: u64,
        deadline: Micros,
    ) -> DagSpec {
        DagSpec::new(
            id,
            name,
            vec![FunctionSpec::new(name, exec_time, setup_time, mem_mb)],
            vec![],
            deadline,
        )
        .expect("single-function dag is always valid")
    }

    /// Linear chain of functions (the shape SAR's two-function DAGs and
    /// the paper's C3 class use).
    pub fn chain(
        id: DagId,
        name: &str,
        stages: &[(Micros, Micros, u64)], // (exec, setup, mem)
        deadline: Micros,
    ) -> DagSpec {
        let functions = stages
            .iter()
            .enumerate()
            .map(|(i, &(exec, setup, mem))| {
                FunctionSpec::new(&format!("{name}-s{i}"), exec, setup, mem)
            })
            .collect();
        let edges = (0..stages.len().saturating_sub(1))
            .map(|i| (i as u16, i as u16 + 1))
            .collect();
        DagSpec::new(id, name, functions, edges, deadline)
            .expect("chain dag is always valid")
    }

    pub fn len(&self) -> usize {
        self.functions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.functions.is_empty()
    }

    /// Static slack budget of the DAG: deadline minus critical-path exec.
    /// Used to normalize the LBS scaling metric (§5.2.2).
    pub fn slack(&self) -> Micros {
        self.deadline.saturating_sub(self.total_cpl)
    }

    pub fn fn_id(&self, idx: u16) -> FnId {
        FnId { dag: self.id, idx }
    }

    /// Serialize back to the JSON upload language.
    pub fn to_json(&self) -> Json {
        json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("deadline_us", Json::Int(self.deadline as i64)),
            (
                "functions",
                Json::Arr(
                    self.functions
                        .iter()
                        .map(|f| {
                            json::obj(vec![
                                ("name", Json::Str(f.name.clone())),
                                ("mem_mb", Json::Int(f.mem_mb as i64)),
                                ("exec_time_us", Json::Int(f.exec_time as i64)),
                                ("setup_time_us", Json::Int(f.setup_time as i64)),
                                ("artifact", Json::Str(f.artifact.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "edges",
                Json::Arr(
                    self.edges
                        .iter()
                        .map(|&(p, c)| {
                            Json::Arr(vec![Json::Int(p as i64), Json::Int(c as i64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The platform's table of uploaded DAGs.
#[derive(Debug, Default)]
pub struct DagRegistry {
    dags: Vec<DagSpec>,
}

impl DagRegistry {
    pub fn new() -> Self {
        DagRegistry::default()
    }

    /// Register a DAG built by the caller with a placeholder id; the
    /// registry assigns the real dense id.
    pub fn register(&mut self, mut dag: DagSpec) -> DagId {
        let id = DagId(self.dags.len() as u32);
        dag.id = id;
        self.dags.push(dag);
        id
    }

    pub fn get(&self, id: DagId) -> &DagSpec {
        &self.dags[id.0 as usize]
    }

    /// Fallible lookup for externally supplied ids (e.g. a request for
    /// a DAG that was never uploaded).
    pub fn try_get(&self, id: DagId) -> Option<&DagSpec> {
        self.dags.get(id.0 as usize)
    }

    pub fn len(&self) -> usize {
        self.dags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.dags.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &DagSpec> {
        self.dags.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn f(exec: Micros) -> FunctionSpec {
        FunctionSpec::new("f", exec, 200 * MS, 128)
    }

    #[test]
    fn single_function_dag() {
        let d = DagSpec::single(DagId(0), "s", 50 * MS, 200 * MS, 128, 150 * MS);
        assert_eq!(d.roots, vec![0]);
        assert_eq!(d.cpl, vec![50 * MS]);
        assert_eq!(d.total_cpl, 50 * MS);
        assert_eq!(d.slack(), 100 * MS);
        assert_eq!(d.topo, vec![0]);
    }

    #[test]
    fn chain_critical_path() {
        let d = DagSpec::chain(
            DagId(0),
            "c",
            &[(10 * MS, 100 * MS, 128), (20 * MS, 100 * MS, 128), (30 * MS, 100 * MS, 128)],
            100 * MS,
        );
        assert_eq!(d.total_cpl, 60 * MS);
        assert_eq!(d.cpl, vec![60 * MS, 50 * MS, 30 * MS]);
        assert_eq!(d.roots, vec![0]);
        assert_eq!(d.children[0], vec![1]);
        assert_eq!(d.parent_count, vec![0, 1, 1]);
    }

    #[test]
    fn diamond_critical_path_takes_max_branch() {
        //      0 (10)
        //     / \
        //  1(5)  2(50)
        //     \ /
        //      3 (10)
        let d = DagSpec::new(
            DagId(1),
            "diamond",
            vec![f(10 * MS), f(5 * MS), f(50 * MS), f(10 * MS)],
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            200 * MS,
        )
        .unwrap();
        assert_eq!(d.total_cpl, 70 * MS); // 10 + 50 + 10
        assert_eq!(d.cpl[0], 70 * MS);
        assert_eq!(d.cpl[1], 15 * MS);
        assert_eq!(d.cpl[2], 60 * MS);
        assert_eq!(d.cpl[3], 10 * MS);
        assert_eq!(d.roots, vec![0]);
        // topo: parents before children
        let pos: Vec<usize> = (0..4u16)
            .map(|i| d.topo.iter().position(|&x| x == i).unwrap())
            .collect();
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn multiple_roots_and_sinks() {
        let d = DagSpec::new(
            DagId(0),
            "multi",
            vec![f(10 * MS), f(20 * MS), f(5 * MS)],
            vec![(0, 2), (1, 2)],
            100 * MS,
        )
        .unwrap();
        assert_eq!(d.roots, vec![0, 1]);
        assert_eq!(d.total_cpl, 25 * MS); // max(10, 20) + 5
    }

    #[test]
    fn rejects_invalid() {
        assert_eq!(
            DagSpec::new(DagId(0), "e", vec![], vec![], MS).unwrap_err(),
            DagError::Empty("e".into())
        );
        assert!(matches!(
            DagSpec::new(DagId(0), "x", vec![f(1)], vec![(0, 1)], MS).unwrap_err(),
            DagError::BadEdge(_, 1)
        ));
        assert!(matches!(
            DagSpec::new(DagId(0), "x", vec![f(1), f(1)], vec![(0, 1), (1, 0)], MS)
                .unwrap_err(),
            DagError::Cyclic(_)
        ));
        assert!(matches!(
            DagSpec::new(DagId(0), "x", vec![f(1)], vec![(0, 0)], MS).unwrap_err(),
            DagError::SelfEdge(_, 0)
        ));
        assert!(matches!(
            DagSpec::new(
                DagId(0),
                "x",
                vec![f(1), f(1)],
                vec![(0, 1), (0, 1)],
                MS
            )
            .unwrap_err(),
            DagError::DuplicateEdge(_, 0, 1)
        ));
        assert!(matches!(
            DagSpec::new(DagId(0), "x", vec![f(1)], vec![], 0).unwrap_err(),
            DagError::ZeroDeadline(_)
        ));
    }

    #[test]
    fn slack_saturates_at_zero() {
        let d = DagSpec::single(DagId(0), "tight", 100 * MS, 0, 128, 50 * MS);
        assert_eq!(d.slack(), 0);
    }

    #[test]
    fn registry_assigns_dense_ids() {
        let mut reg = DagRegistry::new();
        let a = reg.register(DagSpec::single(DagId(99), "a", MS, MS, 128, 10 * MS));
        let b = reg.register(DagSpec::single(DagId(99), "b", MS, MS, 128, 10 * MS));
        assert_eq!(a, DagId(0));
        assert_eq!(b, DagId(1));
        assert_eq!(reg.get(a).name, "a");
        assert_eq!(reg.get(b).id, DagId(1));
        assert_eq!(reg.len(), 2);
        assert!(reg.try_get(DagId(1)).is_some());
        assert!(reg.try_get(DagId(2)).is_none());
    }

    #[test]
    fn json_roundtrip_via_spec_language() {
        let d = DagSpec::chain(
            DagId(0),
            "rt",
            &[(10 * MS, 100 * MS, 128), (20 * MS, 150 * MS, 256)],
            300 * MS,
        );
        let text = d.to_json().to_string();
        let back = parse_dag_json(DagId(0), &text).unwrap();
        assert_eq!(back.name, d.name);
        assert_eq!(back.deadline, d.deadline);
        assert_eq!(back.edges, d.edges);
        assert_eq!(back.functions.len(), 2);
        assert_eq!(back.functions[1].mem_mb, 256);
        assert_eq!(back.total_cpl, d.total_cpl);
    }
}
