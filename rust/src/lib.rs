//! # Archipelago
//!
//! A reproduction of *"Archipelago: A Scalable Low-Latency Serverless
//! Platform"* (Singhvi et al., 2019) as a three-layer Rust + JAX + Pallas
//! stack. This crate is Layer 3: the serving control plane — load
//! balancing service (LBS), semi-global schedulers (SGS) over partitioned
//! worker pools, proactive sandbox management — plus every substrate it
//! needs (discrete-event cluster simulation, workload generation, metrics,
//! baselines) and a PJRT runtime that executes the AOT-compiled JAX/Pallas
//! function bodies with Python nowhere on the request path.
//!
//! ## Layout
//!
//! * [`util`] — offline substrates: JSON, RNG + distributions, stats,
//!   CLI, bench harness, property testing, logging.
//! * [`config`] — typed platform configuration.
//! * [`dag`] — the application model: DAGs of functions with deadlines.
//! * [`sim`] — discrete-event engine + virtual clock.
//! * [`sandbox`] — sandbox lifecycle + proactive memory pool.
//! * [`worker`] — worker-pool machines and per-core execution.
//! * [`sgs`] — semi-global scheduler: SRSF queue, demand estimator,
//!   placement + eviction policies (§4).
//! * [`lbs`] — load balancing service: consistent hashing, lottery
//!   routing, per-DAG SGS scaling (§5).
//! * [`platform`] — full-system assembly + request lifecycle.
//! * [`baseline`] — the paper's comparison stacks (§2.4, §7.1).
//! * [`workload`] — arrival processes, C1–C4 classes, SAR synthesis,
//!   pre-materialized schedules.
//! * [`loadgen`] — open-loop wall-clock load harness (deadline
//!   attainment against the real-time server).
//! * [`metrics`] — collectors and reports.
//! * [`state_store`] — durable service state + fault tolerance (§6.1).
//! * [`runtime`] — PJRT client wrapper executing `artifacts/*.hlo.txt`.
//! * [`experiments`] — one harness per paper table/figure (§7).

#![forbid(unsafe_code)]

pub mod state_store;
pub mod util;

pub mod baseline;
pub mod config;
pub mod dag;
pub mod experiments;
pub mod lbs;
pub mod loadgen;
pub mod metrics;
pub mod platform;
pub mod runtime;
pub mod sandbox;
pub mod sgs;
pub mod sim;
pub mod worker;
pub mod workload;
