//! Deterministic PRNG + the distributions the platform needs.
//!
//! Core generator is splitmix64-seeded **xoshiro256++** — fast, tiny state,
//! excellent statistical quality for simulation work. On top of it:
//! uniform ranges, exponential (request inter-arrivals, §4.3.1's model),
//! Poisson (demand estimation cross-checks and workload synthesis), normal
//! (Box–Muller, for noisy execution times), log-normal (SAR code-size /
//! exec-time synthesis) and weighted choice (lottery scheduling, §5.2.3).
//!
//! Every component that needs randomness takes an explicit `&mut Rng`
//! derived from the experiment seed, so whole macrobenchmarks replay
//! bit-identically.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            spare_normal: None,
        }
    }

    /// Derive an independent child stream (per-DAG / per-class streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)`; panics if `lo >= hi`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "empty range [{lo}, {hi})");
        let span = hi - lo;
        // Lemire's nearly-divisionless unbiased method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Uniform float in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        // Avoid ln(0) by using 1 - U in (0, 1].
        -(1.0 - self.f64()).ln() / lambda
    }

    /// Standard normal via Box–Muller with spare caching.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with explicit mean / std-dev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal given the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda; for large lambda the
    /// normal approximation with continuity correction (adequate for
    /// workload synthesis — estimator-side quantiles use the exact CDF in
    /// `poisson_inv_cdf`, not this sampler).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        let z = self.normal();
        let v = lambda + z * lambda.sqrt() + 0.5;
        if v < 0.0 {
            0
        } else {
            v as u64
        }
    }

    /// Weighted index choice; weights must be non-negative with a positive
    /// sum. This is the lottery draw of §5.2.3.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && total.is_finite(),
            "weighted_choice needs positive finite total, got {total}"
        );
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            debug_assert!(*w >= 0.0);
            if target < *w {
                return i;
            }
            target -= w;
        }
        // float round-off: return last index with positive weight
        weights
            .iter()
            .rposition(|w| *w > 0.0)
            .expect("positive total implies a positive weight")
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.range_usize(0, i + 1);
            items.swap(i, j);
        }
    }

    /// Sample one element uniformly.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.range_usize(0, items.len())]
    }
}

/// Exact Poisson inverse CDF: smallest k with `P(X <= k) >= q`.
///
/// This is the estimator's core primitive (§4.3.1, Fig 5): given the SLA
/// quantile (e.g. 0.99) and the expected arrivals `lambda` in interval T,
/// it returns the provisioning count. Computed by direct summation of
/// pmf terms in stable recursive form; lambda in this system is bounded by
/// (peak RPS × T) which stays ≪ 10^5, so summation is fast and exact
/// enough (term-wise multiplicative recurrence, no factorials).
pub fn poisson_inv_cdf(q: f64, lambda: f64) -> u64 {
    assert!((0.0..1.0).contains(&q) || q == 1.0, "quantile {q}");
    assert!(lambda >= 0.0);
    if lambda == 0.0 {
        return 0;
    }
    // For very large lambda fall back to normal approximation to bound work.
    if lambda > 1e6 {
        let z = normal_inv_cdf(q);
        let v = lambda + z * lambda.sqrt() + 0.5;
        return if v < 0.0 { 0 } else { v as u64 };
    }
    let mut k = 0u64;
    // work in log space to avoid underflow for large lambda:
    // pmf(0) = exp(-lambda)
    let mut log_pmf = -lambda;
    let mut cdf = log_pmf.exp();
    let target = q.min(1.0 - 1e-15);
    while cdf < target {
        k += 1;
        log_pmf += lambda.ln() - (k as f64).ln();
        cdf += log_pmf.exp();
        if k > 100_000_000 {
            break; // defensive; unreachable for sane inputs
        }
    }
    k
}

/// Acklam's rational approximation to the standard normal inverse CDF.
/// Max relative error ~1.15e-9 — plenty for provisioning quantiles.
pub fn normal_inv_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "p must be in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn fork_streams_diverge() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng::new(4);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.range_u64(5, 15);
            assert!((5..15).contains(&v));
            seen[(v - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values in range should appear");
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.005, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn poisson_small_lambda_moments() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_large_lambda_moments() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.poisson(500.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 500.0).abs() < 1.0, "mean {mean}");
    }

    #[test]
    fn poisson_inv_cdf_known_values() {
        // lambda=10: P(X<=15)≈0.9513, P(X<=18)≈0.9928, P(X<=20)≈0.9984
        assert_eq!(poisson_inv_cdf(0.95, 10.0), 15);
        assert_eq!(poisson_inv_cdf(0.99, 10.0), 18);
        assert_eq!(poisson_inv_cdf(0.5, 10.0), 10);
        assert_eq!(poisson_inv_cdf(0.99, 0.0), 0);
        // monotone in q and lambda
        assert!(poisson_inv_cdf(0.999, 10.0) >= poisson_inv_cdf(0.9, 10.0));
        assert!(poisson_inv_cdf(0.99, 50.0) >= poisson_inv_cdf(0.99, 10.0));
    }

    #[test]
    fn poisson_inv_cdf_matches_sampling() {
        // empirical 99th percentile of Poisson(20) should be close
        let mut r = Rng::new(9);
        let mut xs: Vec<u64> = (0..100_000).map(|_| r.poisson(20.0)).collect();
        xs.sort_unstable();
        let emp = xs[(0.99 * xs.len() as f64) as usize];
        let exact = poisson_inv_cdf(0.99, 20.0);
        assert!((emp as i64 - exact as i64).abs() <= 1, "{emp} vs {exact}");
    }

    #[test]
    fn normal_inv_cdf_symmetry_and_known() {
        assert!((normal_inv_cdf(0.5)).abs() < 1e-8);
        assert!((normal_inv_cdf(0.975) - 1.959964).abs() < 1e-4);
        assert!((normal_inv_cdf(0.025) + 1.959964).abs() < 1e-4);
        assert!((normal_inv_cdf(0.99) - 2.326348).abs() < 1e-4);
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    #[should_panic]
    fn weighted_choice_rejects_zero_total() {
        let mut r = Rng::new(11);
        r.weighted_choice(&[0.0, 0.0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(12);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 2.0) > 0.0);
        }
    }
}
