//! Streaming statistics: percentile sketches, EWMA, counters.
//!
//! The paper reports tail latencies (99%, 99.9%-ile), queuing-delay
//! distributions and EWMA-based rate estimates (§4.3.1, §5.2.1). This
//! module provides:
//!
//! * [`Ewma`] — the exact estimator primitive from §4.3.1/§5.2.1.
//! * [`LogHistogram`] — HDR-style log-bucketed histogram: ~0.5% relative
//!   error per bucket, O(1) record, used for all latency metrics so
//!   million-request macrobenchmarks stay O(buckets) in memory.
//! * [`Summary`] — exact small-sample percentiles (sorted vec) for
//!   microbenches where exactness matters.

/// Exponentially weighted moving average: `e ← α·x + (1-α)·e`.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha), "alpha {alpha}");
        Ewma { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }

    pub fn get_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Log-bucketed histogram over `u64` values (we use microseconds).
///
/// Buckets: value 0, then for each power-of-two range, `SUBDIV` linear
/// sub-buckets — bounded ~0.8% relative quantile error with 64*SUBDIV
/// buckets total.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

const SUBDIV_BITS: u32 = 5; // 32 sub-buckets per octave
const SUBDIV: u64 = 1 << SUBDIV_BITS;

fn bucket_index(v: u64) -> usize {
    if v < SUBDIV {
        return v as usize; // exact buckets for tiny values
    }
    let msb = 63 - v.leading_zeros() as u64;
    let shift = msb - SUBDIV_BITS as u64;
    let sub = (v >> shift) & (SUBDIV - 1);
    ((msb - SUBDIV_BITS as u64 + 1) * SUBDIV + sub) as usize
}

fn bucket_low(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < SUBDIV {
        return idx;
    }
    let octave = idx / SUBDIV - 1;
    let sub = idx % SUBDIV;
    (SUBDIV + sub) << octave
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; ((64 - SUBDIV_BITS as usize) + 1) * SUBDIV as usize],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.total += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: u64, n: u64) {
        self.counts[bucket_index(v)] += n;
        self.total += n;
        self.sum += v as u128 * n as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum as f64 / self.total as f64
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile in `[0, 1]`; returns the low edge of the containing
    /// bucket, clamped by the observed min/max for tight small-sample
    /// behaviour.
    pub fn quantile(&self, q: f64) -> u64 {
        assert!((0.0..=1.0).contains(&q));
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_low(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// (p50, p90, p99, p999, max) — the paper's reporting set.
    pub fn tail_summary(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.quantile(0.999),
            self.max(),
        )
    }
}

/// Exact-percentile summary: keeps every sample. For microbenchmarks and
/// tests, not for million-request runs.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    sorted: bool,
}

impl Summary {
    pub fn new() -> Self {
        Summary::default()
    }

    pub fn record(&mut self, v: f64) {
        self.samples.push(v);
        self.sorted = false;
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn std_dev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>()
            / (n - 1) as f64)
            .sqrt()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // total_cmp is a total order over all f64 values (NaN sorts
            // after +inf), so a stray NaN sample skews the extreme tail
            // instead of panicking mid-experiment.
            self.samples.sort_by(|a, b| a.total_cmp(b));
            self.sorted = true;
        }
    }

    /// Nearest-rank percentile; `q` in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        let n = self.samples.len();
        let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
        self.samples[rank - 1]
    }

    pub fn min(&mut self) -> f64 {
        self.quantile(0.0).min(
            self.samples
                .first()
                .copied()
                .unwrap_or(0.0),
        )
    }

    pub fn max(&mut self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.ensure_sorted();
        *self.samples.last().unwrap()
    }
}

/// Windowed mean over the most recent `capacity` observations — used for
/// the queuing-delay windows the LBS scaling decision reads (§5.2.1:
/// "having a window ensures the system does not react to transient
/// changes").
#[derive(Debug, Clone)]
pub struct Window {
    buf: Vec<f64>,
    capacity: usize,
    next: usize,
    filled: bool,
}

impl Window {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        Window {
            buf: Vec::with_capacity(capacity),
            capacity,
            next: 0,
            filled: false,
        }
    }

    pub fn observe(&mut self, v: f64) {
        if self.buf.len() < self.capacity {
            self.buf.push(v);
            if self.buf.len() == self.capacity {
                self.filled = true;
            }
        } else {
            self.buf[self.next] = v;
            self.next = (self.next + 1) % self.capacity;
            self.filled = true;
        }
    }

    /// True once `capacity` observations have arrived since the last reset.
    pub fn is_full(&self) -> bool {
        self.filled
    }

    pub fn mean(&self) -> Option<f64> {
        if self.buf.is_empty() {
            None
        } else {
            Some(self.buf.iter().sum::<f64>() / self.buf.len() as f64)
        }
    }

    pub fn reset(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.filled = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_observation_is_identity() {
        let mut e = Ewma::new(0.2);
        assert_eq!(e.get(), None);
        assert_eq!(e.observe(10.0), 10.0);
        let v = e.observe(20.0);
        assert!((v - 12.0).abs() < 1e-12); // 0.2*20 + 0.8*10
    }

    #[test]
    fn ewma_converges_to_constant_input() {
        let mut e = Ewma::new(0.3);
        for _ in 0..200 {
            e.observe(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_reset() {
        let mut e = Ewma::new(0.5);
        e.observe(1.0);
        e.reset();
        assert_eq!(e.get(), None);
        assert_eq!(e.observe(9.0), 9.0);
    }

    #[test]
    fn bucket_index_monotone_and_invertible_lowedge() {
        let mut prev = 0;
        for v in [0u64, 1, 5, 31, 32, 33, 63, 64, 100, 1000, 1 << 20, u64::MAX / 2] {
            let idx = bucket_index(v);
            assert!(idx >= prev || v < 32, "idx {idx} prev {prev} v {v}");
            prev = idx;
            assert!(bucket_low(idx) <= v, "low edge exceeds value for {v}");
        }
    }

    #[test]
    fn histogram_exact_for_small_values() {
        let mut h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), 31);
        assert_eq!(h.count(), 32);
        assert!((h.mean() - 15.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_quantile_relative_error_bounded() {
        let mut h = LogHistogram::new();
        // deterministic exponential-ish spread
        let mut v;
        let mut all: Vec<u64> = Vec::new();
        for i in 0..10_000u64 {
            v = 1 + (i * i * 37) % 1_000_000;
            h.record(v);
            all.push(v);
        }
        all.sort_unstable();
        for q in [0.5, 0.9, 0.99, 0.999] {
            let exact = all[((q * all.len() as f64).ceil() as usize - 1).min(all.len() - 1)];
            let approx = h.quantile(q);
            let rel = (approx as f64 - exact as f64).abs() / exact as f64;
            assert!(rel < 0.05, "q={q} exact={exact} approx={approx}");
        }
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for v in 0..100 {
            a.record(v);
        }
        for v in 100..200 {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), 200);
        assert_eq!(a.max(), 199);
        assert_eq!(a.min(), 0);
    }

    #[test]
    fn histogram_empty() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn summary_exact_percentiles() {
        let mut s = Summary::new();
        for v in 1..=100 {
            s.record(v as f64);
        }
        assert_eq!(s.quantile(0.5), 50.0);
        assert_eq!(s.quantile(0.99), 99.0);
        assert_eq!(s.quantile(1.0), 100.0);
        assert_eq!(s.max(), 100.0);
        assert!((s.mean() - 50.5).abs() < 1e-12);
    }

    #[test]
    fn summary_tolerates_nan_samples() {
        // Regression: `partial_cmp().expect("NaN sample")` used to abort
        // the whole experiment on a single NaN observation.
        let mut s = Summary::new();
        s.record(2.0);
        s.record(f64::NAN);
        s.record(1.0);
        s.record(3.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert!(s.quantile(0.5).is_finite());
        // NaN sorts last under total_cmp, so it lands at the max slot
        // rather than corrupting interior percentiles.
        assert_eq!(s.quantile(0.25), 1.0);
        assert_eq!(s.quantile(0.75), 3.0);
        assert!(s.max().is_nan());
    }

    #[test]
    fn summary_std_dev() {
        let mut s = Summary::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.record(v);
        }
        assert!((s.std_dev() - 2.138).abs() < 0.01);
    }

    #[test]
    fn window_fill_and_reset() {
        let mut w = Window::new(3);
        assert!(!w.is_full());
        assert_eq!(w.mean(), None);
        w.observe(1.0);
        w.observe(2.0);
        assert!(!w.is_full());
        w.observe(3.0);
        assert!(w.is_full());
        assert_eq!(w.mean(), Some(2.0));
        w.observe(4.0); // evicts 1.0
        assert_eq!(w.mean(), Some(3.0));
        w.reset();
        assert!(!w.is_full());
        assert_eq!(w.mean(), None);
    }
}
