//! Leveled stderr logger, env-controlled (`ARCHIPELAGO_LOG=debug|info|warn|error|off`).
//!
//! Deliberately minimal: one global atomic level, zero allocation when the
//! level filters the message out — nothing on the request hot path may
//! allocate for a disabled log line.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Warn as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        3 => Level::Error,
        _ => Level::Off,
    }
}

/// Initialize from the environment; call once from main().
pub fn init_from_env() {
    if let Ok(v) = std::env::var("ARCHIPELAGO_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            "off" | "none" => Level::Off,
            _ => Level::Warn,
        };
        set_level(lvl);
    }
}

pub fn enabled(level: Level) -> bool {
    level >= self::level() && self::level() != Level::Off
}

pub fn log(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Debug => "DEBUG",
            Level::Info => "INFO ",
            Level::Warn => "WARN ",
            Level::Error => "ERROR",
            Level::Off => return,
        };
        eprintln!("[{tag}] {target}: {msg}");
    }
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Debug) {
            $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Info) {
            $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::logging::enabled($crate::util::logging::Level::Warn) {
            $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*));
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_and_enabled() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Off);
        assert!(!enabled(Level::Error));
        set_level(Level::Warn); // restore default for other tests
    }
}
