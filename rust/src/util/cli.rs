//! Minimal command-line parser (clap is unavailable offline).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` — enough for the `archipelago` launcher, the figure
//! harness and the examples. Unknown options are errors; `--help` is
//! synthesized from the declared options.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declared option for help text + validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name} expects a number, got '{v}'"))),
        }
    }
}

/// Command definition: name, about line, options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: false,
            help,
        });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: true,
            help,
        });
        self
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    pub fn help_text(&self, bin: &str) -> String {
        let mut s = format!("{}\n\nUsage: {bin} {} [options]\n\nOptions:\n", self.about, self.name);
        for o in &self.opts {
            let arg = if o.takes_value {
                format!("--{} <value>", o.name)
            } else {
                format!("--{}", o.name)
            };
            s.push_str(&format!("  {arg:<28} {}\n", o.help));
        }
        s.push_str("  --help                       show this help\n");
        s
    }

    /// Parse raw args (excluding binary + subcommand names).
    pub fn parse(&self, raw: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body == "help" {
                    return Err(CliError(self.help_text("archipelago")));
                }
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .spec(name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                let value = if spec.takes_value {
                    match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                            .clone(),
                    }
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} does not take a value")));
                    }
                    "true".to_string()
                };
                args.flags.insert(name.to_string(), value);
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("simulate", "run a simulation")
            .opt("seed", "rng seed")
            .opt("duration", "seconds")
            .flag("verbose", "chatty output")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_options_and_flags() {
        let a = cmd()
            .parse(&s(&["--seed", "7", "--verbose", "pos1", "--duration=30"]))
            .unwrap();
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert!(a.has("verbose"));
        assert_eq!(a.get("duration"), Some("30"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_for_missing() {
        let a = cmd().parse(&s(&[])).unwrap();
        assert_eq!(a.get_u64("seed", 42).unwrap(), 42);
        assert_eq!(a.get_f64("duration", 1.5).unwrap(), 1.5);
        assert_eq!(a.get_or("seed", "x"), "x");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn errors() {
        assert!(cmd().parse(&s(&["--nope"])).is_err());
        assert!(cmd().parse(&s(&["--seed"])).is_err());
        assert!(cmd().parse(&s(&["--verbose=1"])).is_err());
        assert!(cmd().parse(&s(&["--seed", "abc"])).unwrap().get_u64("seed", 0).is_err());
    }

    #[test]
    fn help_raises_with_text() {
        let err = cmd().parse(&s(&["--help"])).unwrap_err();
        assert!(err.0.contains("Usage:"));
        assert!(err.0.contains("--seed"));
    }
}
