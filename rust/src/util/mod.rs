//! Substrate utilities built from scratch for the offline environment.
//!
//! The paper's Go prototype leaned on the Go standard library plus
//! protobuf/Prometheus; this build has no network access to crates.io, so
//! the equivalents live here: a JSON parser/writer ([`json`]), a
//! deterministic PRNG with the distributions the workload generator and
//! estimator need ([`rng`]), streaming statistics ([`stats`]), a CLI
//! argument parser ([`cli`]), a micro-benchmark harness ([`bench`]), a
//! miniature property-testing framework ([`prop`]) and a leveled logger
//! ([`logging`]).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;

pub mod fasthash;
