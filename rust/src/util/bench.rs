//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures closures with warmup, batched timing to amortize clock reads,
//! and exact-percentile reporting — the §7.4 overhead numbers (median +
//! 99%-ile in microseconds) come straight from [`BenchResult`].

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::Summary;

/// One benchmark's measured distribution (per-iteration latencies, ns).
#[derive(Debug)]
pub struct BenchResult {
    pub name: String,
    pub iterations: u64,
    samples_ns: Summary,
}

impl BenchResult {
    pub fn median_ns(&mut self) -> f64 {
        self.samples_ns.quantile(0.5)
    }

    pub fn p99_ns(&mut self) -> f64 {
        self.samples_ns.quantile(0.99)
    }

    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.mean()
    }

    pub fn quantile_ns(&mut self, q: f64) -> f64 {
        self.samples_ns.quantile(q)
    }

    /// `name  median  p99  mean` line in adaptive units.
    pub fn report_line(&mut self) -> String {
        let med = self.median_ns();
        let p99 = self.p99_ns();
        let mean = self.mean_ns();
        format!(
            "{:<44} median={:>10}  p99={:>10}  mean={:>10}  (n={})",
            self.name,
            fmt_ns(med),
            fmt_ns(p99),
            fmt_ns(mean),
            self.iterations,
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    /// iterations per timing sample (amortizes `Instant::now`)
    pub batch: u64,
    pub max_samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            batch: 1,
            max_samples: 50_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(50),
            measure: Duration::from_millis(200),
            batch: 1,
            max_samples: 20_000,
        }
    }

    /// Run `f` repeatedly; each invocation's return value is black-boxed.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        // Warmup phase
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            black_box(f());
        }
        // Measurement
        let mut samples = Summary::new();
        let mut iterations = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.measure && samples.count() < self.max_samples {
            let t0 = Instant::now();
            for _ in 0..self.batch {
                black_box(f());
            }
            let per_iter = t0.elapsed().as_nanos() as f64 / self.batch as f64;
            samples.record(per_iter);
            iterations += self.batch;
        }
        BenchResult {
            name: name.to_string(),
            iterations,
            samples_ns: samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bench {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(30),
            batch: 10,
            max_samples: 10_000,
        };
        let mut r = b.run("noop-ish", || {
            let mut x = 0u64;
            for i in 0..10 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(r.iterations > 0);
        assert!(r.median_ns() > 0.0);
        assert!(r.p99_ns() >= r.median_ns());
        assert!(r.report_line().contains("median="));
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5_000_000_000.0).ends_with(" s"));
    }
}
