//! Miniature property-testing framework (proptest is unavailable offline).
//!
//! Provides seeded random case generation with failure shrinking for the
//! coordinator-invariant tests in `rust/tests/proptests.rs`. A property is
//! a closure over a [`Gen`] source returning `Result<(), String>`; on
//! failure the runner re-runs with smaller size parameters and reports the
//! seed so the case replays deterministically.

use super::rng::Rng;

/// Case generation source: an RNG plus a "size" budget that the runner
/// shrinks after a failure.
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        self.rng.range_u64(lo, hi)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range_usize(lo, hi)
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Vec with length scaled by the current size budget.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let cap = max_len.min(self.size.max(1));
        let len = self.usize(0, cap + 1);
        (0..len).map(|_| f(self)).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize(0, items.len())]
    }
}

/// Outcome of a property run.
#[derive(Debug)]
pub struct PropReport {
    pub cases: usize,
    pub failure: Option<PropFailure>,
}

#[derive(Debug)]
pub struct PropFailure {
    pub seed: u64,
    pub size: usize,
    pub message: String,
    pub shrunk: bool,
}

/// Property-test runner.
pub struct Runner {
    pub cases: usize,
    pub start_size: usize,
    pub base_seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        Runner {
            cases: 128,
            start_size: 32,
            base_seed: seed_from_env(),
        }
    }
}

fn seed_from_env() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA5C1_9E1A_u64 ^ 0x1234_5678)
}

impl Runner {
    pub fn new(cases: usize) -> Self {
        Runner {
            cases,
            ..Runner::default()
        }
    }

    /// Run the property across `cases` seeds; on failure, attempt shrink
    /// by halving the size budget while the failure reproduces.
    pub fn run(
        &self,
        name: &str,
        prop: impl Fn(&mut Gen) -> Result<(), String>,
    ) -> PropReport {
        for case in 0..self.cases {
            let seed = self.base_seed.wrapping_add(case as u64);
            // grow size with case index so early cases are small
            let size = 1 + (self.start_size * (case + 1)) / self.cases;
            let mut gen = Gen {
                rng: Rng::new(seed),
                size,
            };
            if let Err(msg) = prop(&mut gen) {
                // shrink: halve size while still failing with same seed
                let mut best = (size, msg.clone(), false);
                let mut s = size / 2;
                while s >= 1 {
                    let mut g = Gen {
                        rng: Rng::new(seed),
                        size: s,
                    };
                    match prop(&mut g) {
                        Err(m) => {
                            best = (s, m, true);
                            if s == 1 {
                                break;
                            }
                            s /= 2;
                        }
                        Ok(()) => break,
                    }
                }
                return PropReport {
                    cases: case + 1,
                    failure: Some(PropFailure {
                        seed,
                        size: best.0,
                        message: format!("property '{name}': {}", best.1),
                        shrunk: best.2,
                    }),
                };
            }
        }
        PropReport {
            cases: self.cases,
            failure: None,
        }
    }
}

/// Assert a property holds; panics with seed + message on failure.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let report = Runner::new(cases).run(name, prop);
    if let Some(f) = report.failure {
        panic!(
            "{} (seed={}, size={}, shrunk={}) — replay with PROP_SEED={}",
            f.message, f.seed, f.size, f.shrunk, f.seed
        );
    }
}

/// Convenience assertion macro for inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let r = Runner::new(50).run("tautology", |g| {
            let v = g.vec(10, |g| g.u64(0, 100));
            if v.len() <= 10 {
                Ok(())
            } else {
                Err("vec too long".into())
            }
        });
        assert_eq!(r.cases, 50);
        assert!(r.failure.is_none());
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let r = Runner::new(100).run("always-fails-on-large", |g| {
            let v = g.vec(64, |g| g.u64(0, 10));
            if v.len() > 2 {
                Err(format!("len {}", v.len()))
            } else {
                Ok(())
            }
        });
        let f = r.failure.expect("should fail");
        assert!(f.message.contains("len"));
    }

    #[test]
    #[should_panic(expected = "replay with PROP_SEED")]
    fn check_panics_with_seed() {
        check("boom", 10, |_g| Err("nope".into()));
    }

    #[test]
    fn deterministic_given_same_seed() {
        let mk = |seed| {
            let mut g = Gen {
                rng: Rng::new(seed),
                size: 16,
            };
            g.vec(16, |g| g.u64(0, 1000))
        };
        assert_eq!(mk(42), mk(42));
        assert_ne!(mk(42), mk(43));
    }
}
