//! Fast hashing for hot-path maps (perf pass, EXPERIMENTS.md §Perf).
//!
//! std's default SipHash is DoS-resistant but costs ~10–20 ns per lookup;
//! the platform's per-event maps (request table, per-worker sandbox
//! tables) are keyed by internal dense ids that no adversary controls,
//! so a splitmix64 finalizer suffices and measurably raises simulator
//! throughput.

use std::hash::{BuildHasherDefault, Hasher};

/// splitmix64-finalizer hasher for integer-like keys.
#[derive(Default)]
pub struct SplitMixHasher {
    state: u64,
}

impl Hasher for SplitMixHasher {
    fn finish(&self) -> u64 {
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    fn write(&mut self, bytes: &[u8]) {
        // fold arbitrary bytes (used for compound keys like FnId)
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.state = self
                .state
                .rotate_left(29)
                .wrapping_add(u64::from_le_bytes(buf))
                .wrapping_mul(0x9E3779B97F4A7C15);
        }
    }

    fn write_u64(&mut self, i: u64) {
        self.state = self
            .state
            .rotate_left(29)
            .wrapping_add(i)
            .wrapping_mul(0x9E3779B97F4A7C15);
    }

    fn write_u32(&mut self, i: u32) {
        self.write_u64(u64::from(i));
    }

    fn write_u16(&mut self, i: u16) {
        self.write_u64(u64::from(i));
    }
}

/// HashMap with the splitmix hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<SplitMixHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_basic_ops() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1000u64 {
            m.insert(i, (i * 2) as u32);
        }
        for i in 0..1000u64 {
            assert_eq!(m.get(&i), Some(&((i * 2) as u32)));
        }
        assert_eq!(m.len(), 1000);
        m.remove(&500);
        assert_eq!(m.get(&500), None);
    }

    #[test]
    fn hash_distribution_no_catastrophic_collisions() {
        use std::hash::{BuildHasher, Hash};
        let bh: BuildHasherDefault<SplitMixHasher> = Default::default();
        let mut buckets = [0u32; 64];
        for i in 0..64_000u64 {
            let mut h = bh.build_hasher();
            i.hash(&mut h);
            buckets[(h.finish() % 64) as usize] += 1;
        }
        for b in buckets {
            assert!(b > 500 && b < 1500, "bucket skew: {b}");
        }
    }
}
