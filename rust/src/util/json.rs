//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Serves three jobs in the stack: loading `artifacts/manifest.json`
//! written by the Python AOT step, the platform's JSON config files, and
//! the paper's "JSON-based language" for DAG uploads (§3). Supports the
//! full JSON grammar (RFC 8259) minus exotic number edge cases: numbers
//! parse as `f64` with an `i64` fast path preserved for integral values.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so serialization is
/// deterministic (stable key order), which the state-store checksums and
/// golden tests rely on.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and human-readable context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------------------
    // Typed accessors — all return Option, callers add context.
    // ------------------------------------------------------------------

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(n) if n.fract() == 0.0 && n.abs() < 9e18 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_i64().and_then(|i| u64::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_arr().and_then(|a| a.get(idx))
    }

    /// Required-field helpers used by config/DAG loading: same as `get`
    /// but with an error naming the missing key.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str, String> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| format!("field '{key}' must be a string"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, String> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| format!("field '{key}' must be a number"))
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, String> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| format!("field '{key}' must be a non-negative integer"))
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Num(n) => {
                if n.is_finite() {
                    // Shortest roundtrip-ish: Rust's Display for f64 is
                    // shortest-representation since 1.0.
                    out.push_str(&n.to_string());
                } else {
                    // JSON has no Inf/NaN; emit null like serde_json does.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\x08' => out.push_str("\\b"),
            '\x0c' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build a `Json::Obj` from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Parse a complete JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
        }
    }

    fn literal(&mut self, text: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(value)
        } else {
            Err(self.err(&format!("invalid literal, expected '{text}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(map))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(items))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\x08'),
                    Some(b'f') => out.push('\x0c'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired high surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(combined)
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired low surrogate"));
                        } else {
                            char::from_u32(cp)
                        };
                        match c {
                            Some(c) => out.push(c),
                            None => return Err(self.err("invalid unicode escape")),
                        }
                    }
                    _ => return Err(self.err("invalid escape sequence")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8 sequence"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v: u32 = 0;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid hex digit in \\u escape")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required after decimal point"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("-2.5e-1").unwrap(), Json::Num(-0.25));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_structures() {
        let v = parse(r#"{"a": [1, 2.5, "x"], "b": {"c": null}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().at(0).unwrap().as_i64(), Some(1));
        assert_eq!(v.get("a").unwrap().at(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("a").unwrap().at(2).unwrap().as_str(), Some("x"));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn parse_string_escapes() {
        let v = parse(r#""a\nb\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo 世界"));
    }

    #[test]
    fn parse_errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("01").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"\\x\"").is_err());
        assert!(parse("\"\\ud800\"").is_err()); // unpaired surrogate
        assert!(parse("nul").is_err());
        assert!(parse("+1").is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(300) + &"]".repeat(300);
        assert!(parse(&deep).is_err());
        let ok = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"b":[1,2.5,"x",null,true],"a":{"k":"v"}}"#;
        let v = parse(src).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v);
        let pretty = v.to_pretty();
        assert_eq!(parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn roundtrip_escapes() {
        let v = Json::Str("line\nquote\" back\\ tab\t ctrl\x01".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn int_float_accessors() {
        assert_eq!(parse("5").unwrap().as_f64(), Some(5.0));
        assert_eq!(parse("5.0").unwrap().as_i64(), Some(5));
        assert_eq!(parse("5.5").unwrap().as_i64(), None);
        assert_eq!(parse("-1").unwrap().as_u64(), None);
    }

    #[test]
    fn req_helpers() {
        let v = parse(r#"{"name":"x","n":3}"#).unwrap();
        assert_eq!(v.req_str("name").unwrap(), "x");
        assert_eq!(v.req_u64("n").unwrap(), 3);
        assert!(v.req_str("missing").is_err());
        assert!(v.req_f64("name").is_err());
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn obj_builder() {
        let v = obj(vec![("x", Json::Int(1)), ("y", Json::Str("z".into()))]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":"z"}"#);
    }
}
