//! Worker model: one machine of an SGS's worker pool (§4.1, §6).
//!
//! Every worker runs an *execution manager* daemon that owns a set of CPU
//! cores and the worker's sandbox table. The SGS dispatches function
//! requests to a worker's core; sandbox allocation/eviction requests
//! arrive from the SGS's sandbox manager. In simulation the execution
//! manager is this state struct plus completion events; in real-execution
//! mode (`platform::realtime`) it is a thread pool invoking PJRT
//! executables through [`crate::runtime`].

use crate::dag::FnId;
use crate::sandbox::{SandboxError, SandboxTable};

/// Worker index within its SGS pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u16);

/// One worker machine's state.
#[derive(Debug, Clone)]
pub struct Worker {
    pub id: WorkerId,
    cores_total: u32,
    cores_busy: u32,
    pub sandboxes: SandboxTable,
    alive: bool,
    /// Incremented on every failure; dispatches carry the epoch they
    /// started under so completions from a previous life are discarded.
    epoch: u64,
}

impl Worker {
    pub fn new(id: WorkerId, cores: u32, pool_mb: u64) -> Self {
        Worker {
            id,
            cores_total: cores,
            cores_busy: 0,
            sandboxes: SandboxTable::new(pool_mb),
            alive: true,
            epoch: 0,
        }
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn cores_total(&self) -> u32 {
        self.cores_total
    }

    pub fn cores_free(&self) -> u32 {
        if self.alive {
            self.cores_total - self.cores_busy
        } else {
            0
        }
    }

    pub fn has_free_core(&self) -> bool {
        self.cores_free() > 0
    }

    pub fn is_alive(&self) -> bool {
        self.alive
    }

    /// Occupy a core for a dispatched function.
    pub fn occupy_core(&mut self) {
        assert!(self.has_free_core(), "dispatch to a worker with no free core");
        self.cores_busy += 1;
    }

    /// Release a core on function completion.
    pub fn release_core(&mut self) {
        assert!(self.cores_busy > 0, "core release underflow");
        self.cores_busy -= 1;
    }

    /// Fail-stop: drop all state; in-flight requests are the platform's
    /// problem (§6.1 — the failure detector notifies the SGS which
    /// updates its cluster view).
    pub fn fail(&mut self) {
        self.alive = false;
        self.cores_busy = 0;
        self.epoch += 1;
        let pool = self.sandboxes.pool_total_mb();
        self.sandboxes = SandboxTable::new(pool);
    }

    /// Bring a replacement machine online (empty sandbox table).
    pub fn recover(&mut self) {
        self.alive = true;
    }

    /// Can this worker run `f` right now from a warm sandbox?
    pub fn has_warm(&self, f: FnId) -> bool {
        self.alive && self.sandboxes.warm_idle(f) > 0
    }

    /// Can a cold start fit (pool memory available or evictable)?
    pub fn can_host_cold(&self, mem_mb: u64) -> bool {
        self.alive
            && (self.sandboxes.has_pool_mem(mem_mb)
                || self.evictable_mem_mb() + self.sandboxes.pool_free_mb() >= mem_mb)
    }

    fn evictable_mem_mb(&self) -> u64 {
        self.sandboxes
            .evictable()
            .map(|(_, count, mem, _, _)| count as u64 * mem)
            .sum()
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        if self.cores_busy > self.cores_total {
            return Err(format!(
                "worker {}: busy {} > total {}",
                self.id.0, self.cores_busy, self.cores_total
            ));
        }
        self.sandboxes.check_invariants()
    }
}

/// A pool of workers under one SGS, with the free-core index the
/// scheduler's dispatch loop uses.
#[derive(Debug, Clone)]
pub struct WorkerPool {
    pub workers: Vec<Worker>,
}

impl WorkerPool {
    pub fn new(count: usize, cores: u32, pool_mb: u64) -> Self {
        WorkerPool {
            workers: (0..count)
                .map(|i| Worker::new(WorkerId(i as u16), cores, pool_mb))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.workers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.workers.is_empty()
    }

    pub fn get(&self, id: WorkerId) -> &Worker {
        &self.workers[id.0 as usize]
    }

    pub fn get_mut(&mut self, id: WorkerId) -> &mut Worker {
        &mut self.workers[id.0 as usize]
    }

    pub fn total_free_cores(&self) -> u32 {
        self.workers.iter().map(|w| w.cores_free()).sum()
    }

    pub fn any_free_core(&self) -> bool {
        self.workers.iter().any(|w| w.has_free_core())
    }

    pub fn alive_count(&self) -> usize {
        self.workers.iter().filter(|w| w.is_alive()).count()
    }

    /// Total warm-idle sandboxes of `f` across the pool (lottery tickets).
    pub fn warm_count(&self, f: FnId) -> u32 {
        self.workers
            .iter()
            .filter(|w| w.is_alive())
            .map(|w| w.sandboxes.warm_idle(f))
            .sum()
    }

    /// Total active sandboxes of `f` (for demand reconciliation).
    pub fn active_count(&self, f: FnId) -> u32 {
        self.workers
            .iter()
            .filter(|w| w.is_alive())
            .map(|w| w.sandboxes.active(f))
            .sum()
    }

    pub fn soft_count(&self, f: FnId) -> u32 {
        self.workers
            .iter()
            .filter(|w| w.is_alive())
            .map(|w| w.sandboxes.soft(f))
            .sum()
    }

    /// Pick the dispatch worker for a ready function request (§4.2: "the
    /// SGS spreads out sandboxes for a function across its workers to
    /// maximize the chances that a proactively allocated sandbox will be
    /// available").
    ///
    /// Preference order:
    /// 1. a free-core worker holding a warm sandbox of `f`;
    /// 2. a free-core worker where a cold start fits;
    /// among candidates in the same tier, most free cores wins (load
    /// spread), ties by lowest id (determinism).
    pub fn pick_dispatch_worker(&self, f: FnId, mem_mb: u64) -> Option<(WorkerId, bool)> {
        // keep max free cores; ties go to the lowest worker id
        let better = |best: &Option<(u32, WorkerId)>, free: u32, id: WorkerId| {
            best.map_or(true, |(c, bid)| free > c || (free == c && id.0 < bid.0))
        };
        let mut best_warm: Option<(u32, WorkerId)> = None;
        let mut best_cold: Option<(u32, WorkerId)> = None;
        for w in &self.workers {
            if !w.is_alive() || !w.has_free_core() {
                continue;
            }
            let free = w.cores_free();
            if w.has_warm(f) {
                if better(&best_warm, free, w.id) {
                    best_warm = Some((free, w.id));
                }
            } else if w.can_host_cold(mem_mb) && better(&best_cold, free, w.id) {
                best_cold = Some((free, w.id));
            }
        }
        if let Some((_, id)) = best_warm {
            return Some((id, true));
        }
        best_cold.map(|(_, id)| (id, false))
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        for w in &self.workers {
            w.check_invariants()?;
        }
        Ok(())
    }
}

/// Re-exported for callers that match on sandbox errors.
pub type WorkerSandboxError = SandboxError;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagId;

    fn fid(i: u16) -> FnId {
        FnId {
            dag: DagId(0),
            idx: i,
        }
    }

    #[test]
    fn core_accounting() {
        let mut w = Worker::new(WorkerId(0), 2, 1024);
        assert_eq!(w.cores_free(), 2);
        w.occupy_core();
        w.occupy_core();
        assert!(!w.has_free_core());
        w.release_core();
        assert_eq!(w.cores_free(), 1);
        w.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "no free core")]
    fn over_occupancy_panics() {
        let mut w = Worker::new(WorkerId(0), 1, 1024);
        w.occupy_core();
        w.occupy_core();
    }

    #[test]
    fn failure_drops_state_and_cores() {
        let mut w = Worker::new(WorkerId(0), 4, 1024);
        w.sandboxes.begin_setup(fid(0), 128).unwrap();
        w.sandboxes.finish_setup(fid(0)).unwrap();
        w.occupy_core();
        w.fail();
        assert!(!w.is_alive());
        assert_eq!(w.cores_free(), 0);
        assert!(!w.has_warm(fid(0)));
        assert_eq!(w.sandboxes.pool_used_mb(), 0);
        w.recover();
        assert_eq!(w.cores_free(), 4);
        assert!(!w.has_warm(fid(0)), "recovered worker starts cold");
    }

    #[test]
    fn pool_pick_prefers_warm_sandbox() {
        let mut p = WorkerPool::new(3, 2, 1024);
        // warm sandbox only on worker 2
        p.get_mut(WorkerId(2)).sandboxes.begin_setup(fid(0), 128).unwrap();
        p.get_mut(WorkerId(2)).sandboxes.finish_setup(fid(0)).unwrap();
        let (id, warm) = p.pick_dispatch_worker(fid(0), 128).unwrap();
        assert_eq!(id, WorkerId(2));
        assert!(warm);
    }

    #[test]
    fn pool_pick_falls_back_to_cold_with_most_free_cores() {
        let mut p = WorkerPool::new(3, 4, 1024);
        p.get_mut(WorkerId(0)).occupy_core();
        p.get_mut(WorkerId(2)).occupy_core();
        let (id, warm) = p.pick_dispatch_worker(fid(1), 128).unwrap();
        assert_eq!(id, WorkerId(1)); // 4 free cores vs 3
        assert!(!warm);
    }

    #[test]
    fn pool_pick_skips_busy_and_dead_workers() {
        let mut p = WorkerPool::new(2, 1, 1024);
        // worker 0 warm but core busy; worker 1 dead
        p.get_mut(WorkerId(0)).sandboxes.begin_setup(fid(0), 128).unwrap();
        p.get_mut(WorkerId(0)).sandboxes.finish_setup(fid(0)).unwrap();
        p.get_mut(WorkerId(0)).occupy_core();
        p.get_mut(WorkerId(1)).fail();
        assert!(p.pick_dispatch_worker(fid(0), 128).is_none());
    }

    #[test]
    fn pool_pick_none_when_memory_everywhere_exhausted() {
        let mut p = WorkerPool::new(1, 2, 100);
        // fill pool with a busy sandbox (not evictable)
        p.get_mut(WorkerId(0)).sandboxes.acquire_cold(fid(0), 100, 0).unwrap();
        assert!(p.pick_dispatch_worker(fid(1), 128).is_none());
    }

    #[test]
    fn pool_pick_allows_cold_via_evictable_memory() {
        let mut p = WorkerPool::new(1, 2, 100);
        let w = p.get_mut(WorkerId(0));
        w.sandboxes.begin_setup(fid(0), 100).unwrap();
        w.sandboxes.finish_setup(fid(0)).unwrap();
        // pool full, but the warm sandbox is evictable
        let (id, warm) = p.pick_dispatch_worker(fid(1), 100).unwrap();
        assert_eq!(id, WorkerId(0));
        assert!(!warm);
    }

    #[test]
    fn pool_counts() {
        let mut p = WorkerPool::new(2, 2, 1024);
        for wid in [WorkerId(0), WorkerId(1)] {
            p.get_mut(wid).sandboxes.begin_setup(fid(0), 128).unwrap();
            p.get_mut(wid).sandboxes.finish_setup(fid(0)).unwrap();
        }
        p.get_mut(WorkerId(0)).sandboxes.soft_evict_one(fid(0)).unwrap();
        assert_eq!(p.warm_count(fid(0)), 1);
        assert_eq!(p.active_count(fid(0)), 1);
        assert_eq!(p.soft_count(fid(0)), 1);
        assert_eq!(p.total_free_cores(), 4);
        assert_eq!(p.alive_count(), 2);
    }
}
