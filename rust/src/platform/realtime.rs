//! Real-time serving mode: threads + real PJRT execution on the request
//! path (the `serve` subcommand and the `ml_serving` example).
//!
//! This is the wall-clock twin of the simulated platform: the same SRSF
//! ordering applies, dispatch is sandbox-aware, and a *cold start* is
//! real work — the worker thread parses the artifact's HLO text and
//! compiles it on its own PJRT client (the xla crate's handles are not
//! `Send`, which conveniently mirrors the paper's per-machine sandboxes:
//! an executable compiled on worker A cannot serve worker B). A *warm*
//! hit reuses the worker's cached executable and costs only the
//! inference.
//!
//! Python never appears here: workers read `artifacts/*.hlo.txt` written
//! at build time.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::config::SchedPolicy;
use crate::runtime::xla;
use crate::runtime::{Manifest, RuntimeError, Tensor};

/// A serving request: run `artifact` on `input`.
pub struct Job {
    pub artifact: String,
    pub input: Vec<f32>,
    /// Relative deadline in µs (drives SRSF ordering).
    pub deadline_us: u64,
    pub reply: Sender<Completion>,
    submitted: Instant,
}

/// Completion record returned to the caller.
#[derive(Debug, Clone)]
pub struct Completion {
    pub artifact: String,
    pub worker: usize,
    pub cold: bool,
    /// Queue wait before a worker picked the job up.
    pub queue_us: u64,
    /// Cold-start (HLO parse + PJRT compile) time, 0 when warm.
    pub setup_us: u64,
    /// Pure inference time.
    pub exec_us: u64,
    /// End-to-end: submit → reply.
    pub e2e_us: u64,
    pub outputs: Vec<Tensor>,
}

struct Shared {
    queue: Mutex<QueueState>,
    cv: Condvar,
}

struct QueueState {
    /// (srsf key, seq, job)
    jobs: Vec<(i64, u64, Job)>,
    seq: u64,
    policy: SchedPolicy,
    /// Which artifacts each worker has compiled (warm sets).
    warm: Vec<HashSet<String>>,
    /// Which workers are currently waiting for work.
    idle: Vec<bool>,
    shutdown: bool,
}

impl QueueState {
    /// Pick the job this worker should run: warm-here first, then SRSF
    /// key, then arrival order (sandbox-aware dispatch). A job that is
    /// warm on some *other idle* worker is left for that worker — the
    /// real-time analogue of routing to the proactive sandbox — unless
    /// this worker is also warm for it.
    fn take_for(&mut self, worker: usize) -> Option<Job> {
        if self.jobs.is_empty() {
            return None;
        }
        let warm_here = &self.warm[worker];
        let mut best: Option<(bool, i64, u64, usize)> = None;
        for (i, (key, seq, job)) in self.jobs.iter().enumerate() {
            let is_warm = warm_here.contains(&job.artifact);
            if !is_warm {
                let better_host_idle = self.idle.iter().enumerate().any(|(w, idle)| {
                    *idle && w != worker && self.warm[w].contains(&job.artifact)
                });
                if better_host_idle {
                    continue; // leave it for the warm worker
                }
            }
            let cand = (!is_warm, *key, *seq);
            let better = match best {
                None => true,
                Some((w, k, s, _)) => cand < (w, k, s),
            };
            if better {
                best = Some((cand.0, cand.1, cand.2, i));
            }
        }
        let (_, _, _, idx) = best?;
        Some(self.jobs.swap_remove(idx).2)
    }
}

/// The real-time server.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pub manifest: Manifest,
}

impl Server {
    /// Start `workers` worker threads serving the given artifact dir.
    /// `prewarm` artifacts are compiled on every worker before the
    /// server accepts jobs (proactive allocation's real-time analogue).
    pub fn start(
        artifact_dir: &std::path::Path,
        workers: usize,
        policy: SchedPolicy,
        prewarm: &[&str],
    ) -> Result<Server, RuntimeError> {
        assert!(workers > 0);
        let manifest = Manifest::load(artifact_dir)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState {
                jobs: Vec::new(),
                seq: 0,
                policy,
                warm: vec![HashSet::new(); workers],
                idle: vec![true; workers],
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let shared = Arc::clone(&shared);
            let dir: PathBuf = artifact_dir.to_path_buf();
            let manifest = manifest.clone();
            let prewarm: Vec<String> = prewarm.iter().map(|s| s.to_string()).collect();
            let ready = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(w, shared, dir, manifest, prewarm, ready);
            }));
        }
        drop(ready_tx);
        for _ in 0..workers {
            ready_rx
                .recv()
                .map_err(|e| RuntimeError::Xla(format!("worker start: {e}")))?
                .map_err(RuntimeError::Xla)?;
        }
        Ok(Server {
            shared,
            handles,
            manifest,
        })
    }

    /// Submit a job; the completion arrives on the returned receiver.
    pub fn submit(
        &self,
        artifact: &str,
        input: Vec<f32>,
        deadline_us: u64,
    ) -> Receiver<Completion> {
        let (tx, rx) = channel();
        let job = Job {
            artifact: artifact.to_string(),
            input,
            deadline_us,
            reply: tx,
            submitted: Instant::now(),
        };
        let mut q = self.shared.queue.lock().unwrap();
        let seq = q.seq;
        q.seq += 1;
        let key = match q.policy {
            // SRSF over relative deadlines: tighter deadline = smaller
            // key = dispatched first among queued jobs.
            SchedPolicy::Srsf => job.deadline_us as i64,
            SchedPolicy::Fifo => seq as i64,
        };
        q.jobs.push((key, seq, job));
        drop(q);
        self.shared.cv.notify_all();
        rx
    }

    /// Drain and stop all workers.
    pub fn shutdown(mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Current warm-set sizes per worker (observability).
    pub fn warm_counts(&self) -> Vec<usize> {
        let q = self.shared.queue.lock().unwrap();
        q.warm.iter().map(|s| s.len()).collect()
    }
}

fn worker_main(
    id: usize,
    shared: Arc<Shared>,
    dir: PathBuf,
    manifest: Manifest,
    prewarm: Vec<String>,
    ready: Sender<Result<(), String>>,
) {
    // Each worker owns its own PJRT client + executable cache — the
    // "sandboxes" of this machine.
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            let _ = ready.send(Err(format!("worker {id}: pjrt: {e}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    for name in &prewarm {
        match compile_artifact(&client, &dir, &manifest, name) {
            Ok(exe) => {
                cache.insert(name.clone(), exe);
            }
            Err(e) => {
                let _ = ready.send(Err(format!("worker {id}: prewarm {name}: {e}")));
                return;
            }
        }
    }
    {
        let mut q = shared.queue.lock().unwrap();
        for name in cache.keys() {
            q.warm[id].insert(name.clone());
        }
    }
    let _ = ready.send(Ok(()));

    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if q.shutdown {
                    return;
                }
                if let Some(job) = q.take_for(id) {
                    q.idle[id] = false;
                    break job;
                }
                q.idle[id] = true;
                q = shared.cv.wait(q).unwrap();
            }
        };
        let queue_us = job.submitted.elapsed().as_micros() as u64;

        // Cold start: parse + compile the artifact on this worker.
        let mut setup_us = 0;
        let cold = !cache.contains_key(&job.artifact);
        if cold {
            let t0 = Instant::now();
            match compile_artifact(&client, &dir, &manifest, &job.artifact) {
                Ok(exe) => {
                    cache.insert(job.artifact.clone(), exe);
                    setup_us = t0.elapsed().as_micros() as u64;
                }
                Err(_) => {
                    continue; // drop job; caller sees a closed channel
                }
            }
        }

        // Execute.
        let entry = manifest.entry(&job.artifact).expect("compiled implies known");
        let dims: Vec<i64> = entry.input_shape.iter().map(|&d| d as i64).collect();
        let t0 = Instant::now();
        let outputs = (|| -> Result<Vec<Tensor>, RuntimeError> {
            let lit = xla::Literal::vec1(job.input.as_slice()).reshape(&dims)?;
            let exe = cache.get(&job.artifact).expect("just ensured");
            let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            let mut out = Vec::with_capacity(parts.len());
            for p in parts {
                out.push(match p.element_type()? {
                    xla::ElementType::F32 => Tensor::F32(p.to_vec::<f32>()?),
                    xla::ElementType::S32 => Tensor::I32(p.to_vec::<i32>()?),
                    xla::ElementType::S64 => Tensor::I64(p.to_vec::<i64>()?),
                    other => {
                        return Err(RuntimeError::Xla(format!("output type {other:?}")))
                    }
                });
            }
            Ok(out)
        })();
        let exec_us = t0.elapsed().as_micros() as u64;

        {
            let mut q = shared.queue.lock().unwrap();
            q.warm[id].insert(job.artifact.clone());
            q.idle[id] = true;
        }
        shared.cv.notify_all();

        if let Ok(outputs) = outputs {
            let _ = job.reply.send(Completion {
                artifact: job.artifact,
                worker: id,
                cold,
                queue_us,
                setup_us,
                exec_us,
                e2e_us: job.submitted.elapsed().as_micros() as u64,
                outputs,
            });
        }
    }
}

fn compile_artifact(
    client: &xla::PjRtClient,
    dir: &std::path::Path,
    manifest: &Manifest,
    name: &str,
) -> Result<xla::PjRtLoadedExecutable, RuntimeError> {
    let entry = manifest
        .entry(name)
        .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
    let path = dir.join(&entry.file);
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn serve_warm_and_cold_jobs() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let server = Server::start(&dir, 2, SchedPolicy::Srsf, &["mlp_infer_b1"]).unwrap();
        // warm path
        let input: Vec<f32> = (0..256).map(|i| (i as f32 * 0.03).cos()).collect();
        let rx = server.submit("mlp_infer_b1", input.clone(), 100_000);
        let c = rx.recv().unwrap();
        assert!(!c.cold, "prewarmed artifact must be warm");
        assert_eq!(c.setup_us, 0);
        let probs = c.outputs[0].as_f32().unwrap();
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        // cold path: anomaly_score not prewarmed
        let input2: Vec<f32> = (0..128).map(|i| i as f32 * 0.05).collect();
        let rx2 = server.submit("anomaly_score_b1", input2, 500_000);
        let c2 = rx2.recv().unwrap();
        assert!(c2.cold);
        assert!(c2.setup_us > 0, "cold start must cost compile time");
        // second hit is warm: sandbox-aware dispatch reuses that worker
        let input3: Vec<f32> = (0..128).map(|i| i as f32 * 0.05).collect();
        let rx3 = server.submit("anomaly_score_b1", input3, 500_000);
        let c3 = rx3.recv().unwrap();
        assert!(!c3.cold, "sandbox-aware routing should reuse the warm worker");
        server.shutdown();
    }

    #[test]
    fn throughput_over_batch_of_requests() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let server = Server::start(&dir, 2, SchedPolicy::Srsf, &["mlp_infer_b1"]).unwrap();
        let input: Vec<f32> = vec![0.25; 256];
        let rxs: Vec<_> = (0..50)
            .map(|_| server.submit("mlp_infer_b1", input.clone(), 100_000))
            .collect();
        let mut cold = 0;
        for rx in rxs {
            let c = rx.recv().unwrap();
            if c.cold {
                cold += 1;
            }
            assert_eq!(c.outputs[0].as_f32().unwrap().len(), 10);
        }
        assert_eq!(cold, 0, "all prewarmed");
        server.shutdown();
    }
}
