//! Real-time serving mode: the wall-clock driver for the sharded
//! coordinator core ([`super::coordinator`]).
//!
//! This is the twin of the simulated platform and — since the
//! coordinator extraction — literally the same code path: requests are
//! admitted into the same request tables, routed by the same LBS,
//! ordered by the same SRSF heap ([`crate::sgs::SchedQueue`]), and
//! placed warm-sandbox-aware by the same dispatch loop. Where the
//! discrete-event driver maps a `Dispatched` effect to a future
//! `FnComplete` event, this driver hands it to a worker thread whose
//! [`WorkerExecutor`](crate::runtime::WorkerExecutor) performs the
//! actual computation; the completion call-back is wall-clock time doing
//! what virtual time does in the simulator.
//!
//! Admission is *non-blocking*: [`Server::submit_dag_async`] routes,
//! enqueues, and returns; the terminal [`RequestResult`] — done or an
//! explicit failure carrying the executor error — is delivered to a
//! caller-supplied [`CompletionSink`] exactly once. One sink can serve
//! any number of in-flight requests, so a single open-loop generator
//! thread ([`crate::loadgen`]) drives the whole cluster without parking
//! a thread per request. The blocking [`Server::submit`] /
//! [`Server::submit_dag`] are thin channel-sink wrappers kept for
//! closed-loop callers.
//!
//! Concurrency (DESIGN.md §Sharding): there is no global lock. Each
//! coordinator [`Shard`] — one SGS, its request states, its metrics,
//! its worker job queues — sits behind its own mutex, and the routing
//! [`Front`] (LBS + request-id allocation) behind a separate
//! short-critical-section lock. Admits to different SGSs, completions,
//! and estimator ticks on different shards run fully in parallel; the
//! paper's "each SGS schedules its worker pool independently" (§5)
//! becomes "each shard lock is independent". No thread ever holds two
//! of these locks at once, so there is no lock-order hazard: cross-
//! shard work travels as [`Effect`] values applied after the local
//! lock is released.
//!
//! A *cold start* is real work — with the PJRT backend the worker
//! thread parses the artifact's HLO text and compiles it on its own
//! client (the xla crate's handles are not `Send`, which conveniently
//! mirrors the paper's per-machine sandboxes: an executable compiled on
//! worker A cannot serve worker B). A *warm* hit reuses the worker's
//! cached executable and costs only the inference. The
//! [`StubExecutorFactory`](crate::runtime::StubExecutorFactory) stands
//! in for PJRT in tests and demos, so the full DAG-serving path runs
//! without artifacts.
//!
//! Python never appears here: workers read `artifacts/*.hlo.txt`
//! written at build time.

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{Config, Micros, SchedPolicy};
use crate::dag::{DagId, DagRegistry, DagSpec, FnId};
use crate::metrics::{Metrics, RequestOutcome, SummaryRow};
use crate::runtime::{ExecutorFactory, Manifest, RuntimeError, Tensor, XlaExecutorFactory};
use crate::sgs::{RequestId, SgsId};
use crate::util::fasthash::FastMap;
use crate::worker::WorkerId;

use super::coordinator::{Coordinator, Effect, Front, Shard};

/// Nominal per-function estimates for artifact-derived single-function
/// DAGs (drive SRSF tie-breaks and the estimator's provisioning; the
/// *measured* costs are whatever the executor actually takes).
const ARTIFACT_EXEC_EST: Micros = 1_000;
const ARTIFACT_SETUP_EST: Micros = 200_000;
const ARTIFACT_DEADLINE: Micros = 1_000_000;

/// Completion record for one executed function.
#[derive(Debug, Clone)]
pub struct FnCompletion {
    pub artifact: String,
    /// Function index within the request's DAG.
    pub fn_idx: u16,
    /// Worker thread that ran it (global thread index across shards).
    pub worker: usize,
    pub cold: bool,
    /// SGS queuing delay before dispatch.
    pub queue_us: u64,
    /// Cold-start (e.g. HLO parse + PJRT compile) time, 0 when warm.
    pub setup_us: u64,
    /// Pure execution time.
    pub exec_us: u64,
    pub outputs: Vec<Tensor>,
}

/// Completion record for a whole DAG request.
#[derive(Debug, Clone)]
pub struct DagCompletion {
    pub req: RequestId,
    /// End-to-end: admit → last function finished.
    pub e2e_us: u64,
    pub deadline_met: bool,
    /// Cold starts among this request's function executions.
    pub cold_starts: u32,
    /// Per-function records in completion order.
    pub functions: Vec<FnCompletion>,
}

/// Single-artifact completion (compatibility shape for [`Server::submit`]).
#[derive(Debug, Clone)]
pub struct Completion {
    pub artifact: String,
    pub worker: usize,
    pub cold: bool,
    /// Queue wait before a worker picked the job up.
    pub queue_us: u64,
    /// Cold-start time, 0 when warm.
    pub setup_us: u64,
    /// Pure inference time.
    pub exec_us: u64,
    /// End-to-end: submit → reply.
    pub e2e_us: u64,
    pub outputs: Vec<Tensor>,
}

/// Knobs for the real-time platform.
#[derive(Debug, Clone)]
pub struct RtOptions {
    /// Coordinator shards (SGSs). Each gets its own worker threads, its
    /// own lock, and an independent scheduling loop; the LBS spreads
    /// DAGs across them.
    pub num_sgs: usize,
    /// Worker threads per SGS (one core each: a thread runs one
    /// function at a time, exactly like a simulated single-core worker).
    pub workers: usize,
    pub policy: SchedPolicy,
    /// Run the §4.3.1 estimator and §5.2 LBS control loops on a
    /// background thread (proactive sandbox allocation in wall-clock
    /// time). Off for deterministic tests.
    pub background_ticks: bool,
    /// Per-worker sandbox memory pool (MB).
    pub pool_mb: u64,
}

impl Default for RtOptions {
    fn default() -> Self {
        RtOptions {
            num_sgs: 1,
            workers: 2,
            policy: SchedPolicy::Srsf,
            background_ticks: true,
            pool_mb: 8 * 1024,
        }
    }
}

/// Terminal result of one admitted request, delivered to its
/// [`CompletionSink`] exactly once.
#[derive(Debug, Clone)]
pub enum RequestResult {
    /// Every function executed; the timing verdict is inside.
    Done(DagCompletion),
    /// The request's lifecycle ended without a usable result: an
    /// executor error, or the server shut down with it still in flight.
    Failed(FailedCompletion),
}

impl RequestResult {
    pub fn req(&self) -> RequestId {
        match self {
            RequestResult::Done(c) => c.req,
            RequestResult::Failed(f) => f.req,
        }
    }
}

/// Explicit failure record — the non-blocking path's replacement for the
/// old "dropped reply channel" signal, which could not say *why*.
///
/// When a function's executor errors, the scheduler still runs the
/// request's remaining functions (the scheduling lifecycle — and with it
/// queue/core accounting — completes exactly as for a success); the
/// first error observed is what `error` carries.
#[derive(Debug, Clone)]
pub struct FailedCompletion {
    pub req: RequestId,
    /// Admit → failure delivery.
    pub e2e_us: u64,
    /// First executor error observed, or the shutdown notice.
    pub error: String,
    /// Functions that did complete before/alongside the failure.
    pub functions: Vec<FnCompletion>,
}

/// Where a request's terminal result is delivered.
///
/// `complete` is called exactly once per admitted request, from a worker
/// thread, *after* the request's home-shard lock has been released — so
/// a sink may take its own locks and may even submit new requests,
/// though it runs on the serving path and should stay cheap. One sink
/// instance may serve many in-flight requests (the open-loop load
/// generator shares a single `Arc` across thousands), which is what
/// lets one generator thread keep the whole cluster busy without
/// parking a thread per request.
pub trait CompletionSink: Send + Sync {
    fn complete(&self, result: RequestResult);
}

/// Results resolved under a shard lock, delivered after its release (a
/// sink must never run with a shard lock held).
type Deliveries = Vec<(Arc<dyn CompletionSink>, RequestResult)>;

fn deliver(done: Deliveries) {
    for (sink, result) in done {
        sink.complete(result);
    }
}

/// The trivial sink behind the blocking [`Server::submit_dag`]: forward
/// `Done` to an mpsc channel; drop it on `Failed`, so the caller
/// observes a closed channel — the pre-sink contract, unchanged.
struct DagChannelSink(Sender<DagCompletion>);

impl CompletionSink for DagChannelSink {
    fn complete(&self, result: RequestResult) {
        if let RequestResult::Done(c) = result {
            let _ = self.0.send(c);
        }
    }
}

/// Single-artifact flavor for [`Server::submit`]: unwraps the one
/// function record into the flat [`Completion`] shape.
struct SingleChannelSink(Sender<Completion>);

impl CompletionSink for SingleChannelSink {
    fn complete(&self, result: RequestResult) {
        if let RequestResult::Done(c) = result {
            if let Some(f) = c.functions.into_iter().next() {
                let _ = self.0.send(Completion {
                    artifact: f.artifact,
                    worker: f.worker,
                    cold: f.cold,
                    queue_us: f.queue_us,
                    setup_us: f.setup_us,
                    exec_us: f.exec_us,
                    e2e_us: c.e2e_us,
                    outputs: f.outputs,
                });
            }
        }
    }
}

/// Per-request driver bookkeeping (the driver-side shadow of a shard's
/// request table; lives on the request's home shard).
struct Pending {
    sink: Arc<dyn CompletionSink>,
    input: Arc<Vec<f32>>,
    /// Wall-clock admit time (for the e2e of a shutdown failure).
    admitted_at: Micros,
    functions: Vec<FnCompletion>,
    /// First executor error observed for this request, if any.
    error: Option<String>,
}

/// Work handed to a worker thread. `worker` is the pool-local id within
/// the thread's own shard.
enum Job {
    Run {
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        artifact: String,
        cold: bool,
        queue_us: u64,
        input: Arc<Vec<f32>>,
    },
    Setup {
        worker: WorkerId,
        epoch: u64,
        f: FnId,
        artifact: String,
        prewarm: bool,
    },
}

/// One worker thread's work, in two lanes: dispatched requests always
/// run before proactive setups, mirroring the simulator where a setup
/// charges memory but never a core — a queued compile must not delay a
/// function the scheduler already placed on this worker.
#[derive(Default)]
struct WorkerQueue {
    runs: VecDeque<Job>,
    setups: VecDeque<Job>,
}

impl WorkerQueue {
    fn pop(&mut self) -> Option<Job> {
        self.runs.pop_front().or_else(|| self.setups.pop_front())
    }
}

/// Everything one shard's lock protects: the coordinator shard plus the
/// driver-side job queues and pending-sink table for requests homed
/// there.
struct ShardRt {
    shard: Shard,
    /// Per worker-thread job queues (indexed by pool-local worker id).
    jobs: Vec<WorkerQueue>,
    pending: FastMap<u64, Pending>,
    shutdown: bool,
}

/// A shard and the condvar its worker threads sleep on.
struct ShardCell {
    state: Mutex<ShardRt>,
    cv: Condvar,
}

/// Prewarm barrier bookkeeping (start-up only).
#[derive(Default)]
struct PrewarmState {
    outstanding: usize,
    error: Option<String>,
}

struct Shared {
    /// Routing front-end: LBS + request-id allocation. Short critical
    /// sections only (a lottery draw + root enqueue construction).
    front: Mutex<Front>,
    /// Immutable after start; readable by every thread without a lock.
    registry: Arc<DagRegistry>,
    cfg: Config,
    shards: Vec<ShardCell>,
    prewarm: Mutex<PrewarmState>,
    prewarm_cv: Condvar,
    start: Instant,
    workers_per_sgs: usize,
    /// artifact name → its single-function DAG (for [`Server::submit`]).
    singles: HashMap<String, DagId>,
    /// Ticker-thread stop flag (worker threads use the per-shard flag).
    shutdown: AtomicBool,
}

impl Shared {
    /// Wall-clock microseconds since server start — the driver's `now`.
    fn now(&self) -> Micros {
        self.start.elapsed().as_micros() as u64
    }
}

fn fn_name(registry: &DagRegistry, f: FnId) -> String {
    registry.get(f.dag).functions[f.idx as usize].name.clone()
}

/// Turn coordinator effects into wall-clock actions *for one locked
/// shard*: `Enqueue`/`Advance` for this shard feed straight back into
/// it (routing overhead is real lock time, not simulated),
/// `Dispatched`/`SetupStarted` become worker jobs, and `RequestDone`
/// resolves the caller's completion sink (pushed to `done`; the caller
/// delivers after releasing this shard's lock). Newly generated effects
/// are processed until quiescent; effects that target another shard (or
/// the front, for §6.1 re-routing) are returned for the caller to apply
/// *after* releasing this shard's lock — no thread ever holds two shard
/// locks.
fn drain_local(
    sh: &mut ShardRt,
    now: Micros,
    fx: &mut Vec<Effect>,
    registry: &DagRegistry,
    done: &mut Deliveries,
) -> Vec<Effect> {
    let my = sh.shard.id();
    let mut remote = Vec::new();
    while !fx.is_empty() {
        let batch: Vec<Effect> = std::mem::take(fx);
        for e in batch {
            match e {
                Effect::Enqueue {
                    sgs,
                    queued,
                    is_root,
                    ..
                } if sgs == my => sh.shard.enqueue(now, queued, is_root, fx),
                Effect::Advance { sgs, req, f, lost } if sgs == my => {
                    sh.shard.advance(now, req, f, lost, fx)
                }
                Effect::Dispatched {
                    sgs,
                    epoch,
                    dispatch: d,
                } if sgs == my => {
                    let artifact = fn_name(registry, d.f);
                    let input = sh
                        .pending
                        .get(&d.req.0)
                        .map(|p| Arc::clone(&p.input))
                        .unwrap_or_default();
                    sh.jobs[d.worker.0 as usize].runs.push_back(Job::Run {
                        worker: d.worker,
                        epoch,
                        req: d.req,
                        f: d.f,
                        artifact,
                        cold: d.cold,
                        queue_us: d.queue_delay,
                        input,
                    });
                }
                Effect::SetupStarted { sgs, epoch, setup } if sgs == my => {
                    let artifact = fn_name(registry, setup.f);
                    sh.jobs[setup.worker.0 as usize]
                        .setups
                        .push_back(Job::Setup {
                            worker: setup.worker,
                            epoch,
                            f: setup.f,
                            artifact,
                            prewarm: false,
                        });
                }
                Effect::RequestDone { req, outcome } => finalize(sh, req, outcome, done),
                other => remote.push(other),
            }
        }
    }
    remote
}

/// Lock shard `sgs`, apply `fx` there, notify its workers, and return
/// whatever escaped to other shards.
fn apply_on_shard(shared: &Shared, sgs: SgsId, now: Micros, mut fx: Vec<Effect>) -> Vec<Effect> {
    let cell = &shared.shards[sgs.0 as usize];
    let mut done = Vec::new();
    let mut st = cell.state.lock().unwrap();
    let remote = drain_local(&mut st, now, &mut fx, &shared.registry, &mut done);
    drop(st);
    cell.cv.notify_all();
    deliver(done);
    remote
}

/// Apply cross-shard effects, one lock at a time, until quiescent.
/// `Reroute` goes through the front (a fresh LBS decision, §6.1); the
/// rest are handed to their target shard.
fn apply_remote(shared: &Shared, now: Micros, fx: Vec<Effect>) {
    let mut queue: VecDeque<Effect> = fx.into();
    while let Some(e) = queue.pop_front() {
        let expanded = match e {
            Effect::Reroute {
                from,
                queued,
                is_root,
            } => {
                let mut sub = Vec::new();
                shared
                    .front
                    .lock()
                    .unwrap()
                    .reroute(now, from, queued, is_root, &mut sub);
                sub
            }
            Effect::Enqueue { sgs, .. }
            | Effect::Dispatched { sgs, .. }
            | Effect::SetupStarted { sgs, .. }
            | Effect::Advance { sgs, .. } => apply_on_shard(shared, sgs, now, vec![e]),
            // A request's RequestDone is emitted under its home shard's
            // lock and resolved there by drain_local, because Pending
            // (completion sink + input) lives on the home shard and does
            // NOT migrate. That is sound today: the realtime server
            // exposes no SGS failure injection, so Reroute/Advance and a
            // deferred RequestDone are unreachable (handled defensively
            // above). If realtime shard failure is ever added, Pending
            // must move together with Shard::install or sinks leak — the
            // assert below turns that silent hang into a loud one.
            Effect::RequestDone { .. } => {
                debug_assert!(
                    false,
                    "RequestDone escaped its home shard: Pending does not migrate; \
                     the caller's reply channel would hang"
                );
                Vec::new()
            }
        };
        // Preserve emission order: expansions go to the queue front.
        for sub in expanded.into_iter().rev() {
            queue.push_front(sub);
        }
    }
}

/// Resolve a finished request: build its terminal [`RequestResult`] and
/// queue it for delivery once the shard lock is released. An executor
/// error becomes an explicit [`RequestResult::Failed`] carrying the
/// error — and is reclassified in the shard's [`Metrics`] so a failed
/// request can never count as deadline-met.
fn finalize(sh: &mut ShardRt, req: RequestId, outcome: RequestOutcome, done: &mut Deliveries) {
    let Some(p) = sh.pending.remove(&req.0) else {
        return;
    };
    let result = match p.error {
        Some(error) => {
            sh.shard.metrics.record_failure(&outcome);
            RequestResult::Failed(FailedCompletion {
                req,
                e2e_us: outcome.e2e_latency(),
                error,
                functions: p.functions,
            })
        }
        None => RequestResult::Done(DagCompletion {
            req,
            e2e_us: outcome.e2e_latency(),
            deadline_met: outcome.deadline_met(),
            cold_starts: outcome.cold_starts,
            functions: p.functions,
        }),
    };
    done.push((p.sink, result));
}

/// The real-time server: per-shard worker threads + optional
/// control-loop ticker around the sharded coordinator core.
pub struct Server {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    ticker: Option<JoinHandle<()>>,
    pub manifest: Manifest,
}

impl Server {
    /// Start a PJRT-backed server over an artifact directory: every
    /// manifest entry becomes a single-function DAG served by
    /// [`Server::submit`]. `prewarm` artifacts are compiled on every
    /// worker before the server accepts jobs (proactive allocation's
    /// real-time analogue).
    pub fn start(
        artifact_dir: &Path,
        workers: usize,
        policy: SchedPolicy,
        prewarm: &[&str],
    ) -> Result<Server, RuntimeError> {
        let manifest = Manifest::load(artifact_dir)?;
        let dags: Vec<DagSpec> = manifest
            .entries
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let mem_mb = (e.vmem_bytes / (1024 * 1024)).max(128);
                DagSpec::single(
                    DagId(i as u32),
                    &e.name,
                    ARTIFACT_EXEC_EST,
                    ARTIFACT_SETUP_EST,
                    mem_mb,
                    ARTIFACT_DEADLINE,
                )
            })
            .collect();
        let factory = Arc::new(XlaExecutorFactory {
            dir: artifact_dir.to_path_buf(),
            manifest: manifest.clone(),
        });
        let opts = RtOptions {
            workers,
            policy,
            ..RtOptions::default()
        };
        Self::start_with(factory, dags, opts, prewarm, manifest)
    }

    /// Start a server over arbitrary DAGs with a custom execution
    /// backend — the general entry point the artifact-based
    /// [`Server::start`] delegates to, and the one tests drive with a
    /// [`StubExecutorFactory`](crate::runtime::StubExecutorFactory).
    pub fn start_with(
        factory: Arc<dyn ExecutorFactory>,
        dags: Vec<DagSpec>,
        opts: RtOptions,
        prewarm: &[&str],
        manifest: Manifest,
    ) -> Result<Server, RuntimeError> {
        assert!(opts.num_sgs > 0, "need at least one SGS shard");
        assert!(opts.workers > 0, "need at least one worker thread");
        let mut registry = DagRegistry::new();
        for dag in dags {
            registry.register(dag);
        }
        let mut singles = HashMap::new();
        for d in registry.iter() {
            if d.len() == 1 {
                singles.insert(d.functions[0].name.clone(), d.id);
            }
        }

        // N SGS shards whose workers are this process's threads, one
        // core each: a thread runs one function at a time, exactly like
        // a simulated single-core worker.
        let mut cfg = Config::default();
        cfg.cluster.num_sgs = opts.num_sgs;
        cfg.cluster.workers_per_sgs = opts.workers;
        cfg.cluster.cores_per_worker = 1;
        cfg.cluster.worker_mem_mb = cfg.cluster.worker_mem_mb.max(opts.pool_mb);
        cfg.cluster.proactive_pool_mb = opts.pool_mb;
        cfg.sgs.sched_policy = opts.policy;
        // Wall-clock overheads are real (lock hold times), not modeled.
        cfg.sgs.sched_overhead = 0;
        cfg.lbs.route_overhead = 0;

        let mut core = Coordinator::new(cfg.clone(), registry, 0, 0x5eed);
        core.register_all_dags();
        let Coordinator { front, shards } = core;
        let registry = Arc::clone(&front.registry);
        let workers_per_sgs = opts.workers;
        let thread_count = shards.len() * workers_per_sgs;
        let shard_cells: Vec<ShardCell> = shards
            .into_iter()
            .map(|shard| ShardCell {
                state: Mutex::new(ShardRt {
                    shard,
                    jobs: (0..workers_per_sgs).map(|_| WorkerQueue::default()).collect(),
                    pending: FastMap::default(),
                    shutdown: false,
                }),
                cv: Condvar::new(),
            })
            .collect();
        let shared = Arc::new(Shared {
            front: Mutex::new(front),
            registry,
            cfg,
            shards: shard_cells,
            prewarm: Mutex::new(PrewarmState::default()),
            prewarm_cv: Condvar::new(),
            start: Instant::now(),
            workers_per_sgs,
            singles,
            shutdown: AtomicBool::new(false),
        });

        // Spawn the worker threads; each builds its own executor.
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let mut handles = Vec::with_capacity(thread_count);
        for t in 0..thread_count {
            let shared = Arc::clone(&shared);
            let factory = Arc::clone(&factory);
            let ready = ready_tx.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(t, shared, factory, ready);
            }));
        }
        drop(ready_tx);
        for _ in 0..thread_count {
            ready_rx
                .recv()
                .map_err(|e| RuntimeError::Xla(format!("worker start: {e}")))?
                .map_err(RuntimeError::Xla)?;
        }

        // Prewarm: proactively set up the named functions on every
        // worker of every shard and wait until the compiles finish (the
        // server accepts no jobs before returning, so this is a clean
        // barrier). The outstanding count is published *before* any job
        // is queued — a worker may pop one the moment its shard's lock
        // is released.
        if !prewarm.is_empty() {
            shared.prewarm.lock().unwrap().outstanding = prewarm.len() * thread_count;
            for name in prewarm {
                let found = shared.registry.iter().find_map(|d| {
                    d.functions
                        .iter()
                        .position(|f| f.name == *name)
                        .map(|i| (d.fn_id(i as u16), d.functions[i].mem_mb))
                });
                let Some((f, mem_mb)) = found else {
                    shutdown_workers(&shared, handles);
                    return Err(RuntimeError::UnknownArtifact(name.to_string()));
                };
                for cell in &shared.shards {
                    let mut st = cell.state.lock().unwrap();
                    for w in 0..workers_per_sgs {
                        let worker = WorkerId(w as u16);
                        // Prewarm promises the artifact warm on *every*
                        // worker before the server accepts jobs — fail
                        // start loudly rather than silently skip one.
                        if st.shard.sgs.pool.get_mut(worker)
                            .sandboxes
                            .begin_setup(f, mem_mb)
                            .is_err()
                        {
                            drop(st);
                            shutdown_workers(&shared, handles);
                            return Err(RuntimeError::Xla(format!(
                                "prewarm {name}: no sandbox capacity for {mem_mb} MB \
                                 on worker {w} (pool {} MB)",
                                opts.pool_mb
                            )));
                        }
                        st.jobs[w].setups.push_back(Job::Setup {
                            worker,
                            epoch: 0,
                            f,
                            artifact: (*name).to_string(),
                            prewarm: true,
                        });
                    }
                    drop(st);
                    cell.cv.notify_all();
                }
            }
            let mut pw = shared.prewarm.lock().unwrap();
            while pw.outstanding > 0 {
                pw = shared.prewarm_cv.wait(pw).unwrap();
            }
            if let Some(e) = pw.error.take() {
                drop(pw);
                shutdown_workers(&shared, handles);
                return Err(RuntimeError::Xla(e));
            }
        }

        // Background control loops (estimator + LBS scaling).
        let ticker = opts.background_ticks.then(|| {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || ticker_main(shared))
        });

        Ok(Server {
            shared,
            handles,
            ticker,
            manifest,
        })
    }

    /// Submit a single-artifact request; the completion arrives on the
    /// returned receiver (closed channel = unknown artifact or executor
    /// failure). A thin blocking wrapper over [`Server::submit_dag_async`].
    pub fn submit(&self, artifact: &str, input: Vec<f32>, deadline_us: u64) -> Receiver<Completion> {
        let (tx, rx) = channel();
        if let Some(&dag) = self.shared.singles.get(artifact) {
            self.submit_dag_async(dag, input, deadline_us, Arc::new(SingleChannelSink(tx)));
        }
        rx
    }

    /// Submit a full DAG request with a per-request deadline: every
    /// function executes (dependency-ordered, warm-sandbox-aware) on the
    /// worker pool, and the aggregate completion arrives on the returned
    /// receiver. An unregistered `dag` — or an executor failure — drops
    /// the channel (the caller observes `recv() == Err`) instead of
    /// panicking the server. A thin blocking wrapper over
    /// [`Server::submit_dag_async`]; use that (and a shared sink) to
    /// distinguish failures explicitly or to keep many requests in
    /// flight from one thread.
    pub fn submit_dag(
        &self,
        dag: DagId,
        input: Vec<f32>,
        deadline_us: u64,
    ) -> Receiver<DagCompletion> {
        let (tx, rx) = channel();
        self.submit_dag_async(dag, input, deadline_us, Arc::new(DagChannelSink(tx)));
        rx
    }

    /// Look up a registered DAG by name (lock-free: the registry is
    /// immutable after start).
    pub fn dag_id(&self, name: &str) -> Option<DagId> {
        self.shared
            .registry
            .iter()
            .find(|d| d.name == name)
            .map(|d| d.id)
    }

    /// A registered DAG's default relative deadline (µs), if known —
    /// what an open-loop driver submits with when it has no per-request
    /// override.
    pub fn dag_deadline(&self, dag: DagId) -> Option<Micros> {
        self.shared.registry.try_get(dag).map(|d| d.deadline)
    }

    /// Non-blocking admission: route and enqueue the request, then
    /// return immediately. The terminal result — done *or failed* — is
    /// delivered to `sink` exactly once, from a worker thread, after the
    /// request's last function settles (or at [`Server::shutdown`] if
    /// the server stops first). Returns the request id, or `None` when
    /// `dag` is not registered: nothing was admitted and the sink is
    /// dropped without being called.
    ///
    /// One sink can be shared across any number of in-flight requests,
    /// so a single generator thread can keep thousands of requests in
    /// flight — the open-loop serving seam ([`crate::loadgen`]).
    pub fn submit_dag_async(
        &self,
        dag: DagId,
        input: Vec<f32>,
        deadline_us: u64,
        sink: Arc<dyn CompletionSink>,
    ) -> Option<RequestId> {
        let now = self.shared.now();
        // Validate against the immutable registry before touching any
        // lock; an unknown DAG admits nothing.
        let spec = self.shared.registry.try_get(dag)?;
        let exec_times: Vec<Micros> = spec.functions.iter().map(|f| f.exec_time).collect();
        let mut fx = Vec::new();
        // Short front critical section: one LBS draw + root construction.
        let admitted = {
            let mut front = self.shared.front.lock().unwrap();
            front.admit(now, dag, exec_times, Some(deadline_us), &mut fx)
        };
        let (req, sgs, state) = admitted?;
        // Home-shard critical section: install state, enqueue roots,
        // drain the dispatch loop. Other shards stay untouched — admits
        // to different SGSs run fully in parallel.
        let cell = &self.shared.shards[sgs.0 as usize];
        let mut done = Vec::new();
        let mut st = cell.state.lock().unwrap();
        st.shard.install(req, state);
        st.pending.insert(
            req.0,
            Pending {
                sink,
                input: Arc::new(input),
                admitted_at: now,
                functions: Vec::new(),
                error: None,
            },
        );
        let remote = drain_local(&mut st, now, &mut fx, &self.shared.registry, &mut done);
        drop(st);
        cell.cv.notify_all();
        deliver(done);
        apply_remote(&self.shared, now, remote);
        Some(req)
    }

    /// Warm sandbox kinds per worker thread (observability), indexed by
    /// global thread id (shard-major).
    pub fn warm_counts(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.shared.shards.len() * self.shared.workers_per_sgs);
        for cell in &self.shared.shards {
            let st = cell.state.lock().unwrap();
            out.extend(st.shard.sgs.warm_kind_counts());
        }
        out
    }

    /// Aggregate latency/deadline metrics across completed requests —
    /// per-shard metrics merged on read.
    pub fn summary(&self) -> SummaryRow {
        let mut m = Metrics::new();
        for cell in &self.shared.shards {
            let st = cell.state.lock().unwrap();
            m.merge(&st.shard.metrics);
        }
        m.summary_row()
    }

    /// Total request-paid cold starts so far, across all shards.
    pub fn total_cold_starts(&self) -> u64 {
        self.shared
            .shards
            .iter()
            .map(|cell| cell.state.lock().unwrap().shard.sgs.cold_starts())
            .sum()
    }

    /// Stop all workers, then fail every request still in flight: the
    /// sink contract — exactly one terminal result per admitted request
    /// — holds even when the server stops with work queued, so an
    /// open-loop driver can always reconcile submitted vs. completed.
    /// (The blocking wrappers' channel sinks translate this failure into
    /// their usual closed-channel signal.) Shutdown failures are not
    /// recorded in [`Metrics`]: those requests never completed their
    /// scheduling lifecycle.
    pub fn shutdown(mut self) {
        shutdown_workers(&self.shared, std::mem::take(&mut self.handles));
        if let Some(t) = self.ticker.take() {
            let _ = t.join();
        }
        let now = self.shared.now();
        for cell in &self.shared.shards {
            // Workers are joined: nobody else can touch `pending` now.
            let done: Deliveries = {
                let mut st = cell.state.lock().unwrap();
                st.pending
                    .drain()
                    .map(|(id, p)| {
                        let result = RequestResult::Failed(FailedCompletion {
                            req: RequestId(id),
                            e2e_us: now.saturating_sub(p.admitted_at),
                            error: "server shut down with the request in flight".into(),
                            functions: p.functions,
                        });
                        (p.sink, result)
                    })
                    .collect()
            };
            deliver(done);
        }
    }
}

/// Start-failure teardown: stop every worker thread and join.
fn shutdown_workers(shared: &Shared, handles: Vec<JoinHandle<()>>) {
    shared.shutdown.store(true, Ordering::SeqCst);
    for cell in &shared.shards {
        let mut st = cell.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        cell.cv.notify_all();
    }
    for h in handles {
        let _ = h.join();
    }
}

fn worker_main(
    t: usize,
    shared: Arc<Shared>,
    factory: Arc<dyn ExecutorFactory>,
    ready: Sender<Result<(), String>>,
) {
    // Each worker owns its own executor — the "sandboxes" of this
    // machine (per-thread PJRT client + executable cache, or the stub).
    let mut exec = match factory.make(t) {
        Ok(e) => e,
        Err(e) => {
            let _ = ready.send(Err(format!("worker {t}: {e}")));
            return;
        }
    };
    let _ = ready.send(Ok(()));
    drop(ready);

    // Shard-major thread layout: this thread serves worker `w` of
    // shard `s`, and only ever takes that shard's lock on the hot path.
    let s = t / shared.workers_per_sgs;
    let w = t % shared.workers_per_sgs;
    let cell = &shared.shards[s];

    loop {
        let job = {
            let mut st = cell.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = st.jobs[w].pop() {
                    break j;
                }
                st = cell.cv.wait(st).unwrap();
            }
        };
        match job {
            Job::Setup {
                worker,
                epoch,
                f,
                artifact,
                prewarm,
            } => {
                let result = exec.warm_up(&artifact);
                let now = shared.now();
                let mut done = Vec::new();
                let mut st = cell.state.lock().unwrap();
                // Mark the sandbox warm even on a failed compile: the
                // executor retries at execute time, and a second failure
                // fails the request — the table and the cache reconverge
                // either way.
                let mut fx = Vec::new();
                st.shard.setup_done(now, worker, epoch, f, &mut fx);
                let remote = drain_local(&mut st, now, &mut fx, &shared.registry, &mut done);
                drop(st);
                cell.cv.notify_all();
                deliver(done);
                apply_remote(&shared, now, remote);
                if prewarm {
                    let mut pw = shared.prewarm.lock().unwrap();
                    pw.outstanding -= 1;
                    if let Err(e) = &result {
                        pw.error
                            .get_or_insert_with(|| format!("worker {t}: prewarm {artifact}: {e}"));
                    }
                    drop(pw);
                    shared.prewarm_cv.notify_all();
                }
            }
            Job::Run {
                worker,
                epoch,
                req,
                f,
                artifact,
                cold,
                queue_us,
                input,
            } => {
                // Cold start: the real compile cost lands here, on the
                // request path, exactly where the simulator charges
                // `setup_time`.
                let mut setup_us = 0u64;
                if !exec.is_warm(&artifact) {
                    let t0 = Instant::now();
                    let _ = exec.warm_up(&artifact); // failure surfaces below
                    setup_us = t0.elapsed().as_micros() as u64;
                }
                let t0 = Instant::now();
                let result = exec.execute(&artifact, &input);
                let exec_us = t0.elapsed().as_micros() as u64;

                let now = shared.now();
                let mut done = Vec::new();
                let mut st = cell.state.lock().unwrap();
                if let Some(p) = st.pending.get_mut(&req.0) {
                    match result {
                        Ok(outputs) => p.functions.push(FnCompletion {
                            artifact,
                            fn_idx: f.idx,
                            worker: t,
                            cold,
                            queue_us,
                            setup_us,
                            exec_us,
                            outputs,
                        }),
                        // First error wins; it reaches the caller in the
                        // explicit FailedCompletion at finalize time.
                        Err(e) => {
                            if p.error.is_none() {
                                p.error = Some(format!("{artifact}: {e}"));
                            }
                        }
                    }
                }
                let mut fx = Vec::new();
                st.shard.fn_complete(now, worker, epoch, req, f, &mut fx);
                let remote = drain_local(&mut st, now, &mut fx, &shared.registry, &mut done);
                drop(st);
                cell.cv.notify_all();
                deliver(done);
                apply_remote(&shared, now, remote);
            }
        }
    }
}

/// Background control loops: the §4.3.1 estimator tick per shard and
/// the §5.2 LBS scaling evaluation, in wall-clock time. Each shard is
/// locked on its own — a tick on shard 0 never blocks dispatching on
/// shard 1. Sleeps in short slices so shutdown stays prompt.
fn ticker_main(shared: Arc<Shared>) {
    const SLICE: Duration = Duration::from_millis(20);
    let est_interval = shared.cfg.sgs.estimate_interval;
    let control_interval = shared.cfg.lbs.control_interval;
    let mut last_est: Micros = 0;
    let mut last_control: Micros = 0;
    loop {
        std::thread::sleep(SLICE);
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let now = shared.now();
        if now.saturating_sub(last_est) >= est_interval {
            last_est = now;
            for cell in &shared.shards {
                let mut fx = Vec::new();
                let mut done = Vec::new();
                let mut st = cell.state.lock().unwrap();
                if st.shutdown {
                    return;
                }
                let reports = st.shard.estimator_tick(now, &mut fx);
                let remote = drain_local(&mut st, now, &mut fx, &shared.registry, &mut done);
                drop(st);
                cell.cv.notify_all();
                deliver(done);
                apply_remote(&shared, now, remote);
                if !reports.is_empty() {
                    let mut front = shared.front.lock().unwrap();
                    for (dag_id, report) in reports {
                        front.lbs.update_report(dag_id, report);
                    }
                }
            }
        }
        if now.saturating_sub(last_control) >= control_interval {
            last_control = now;
            // Front critical section: the per-DAG scaling decisions.
            let actions: Vec<crate::lbs::ScaleAction> = {
                let mut front = shared.front.lock().unwrap();
                let mut v = Vec::new();
                for dag in shared.registry.iter() {
                    v.extend(front.lbs.control_tick(dag.id, dag.slack()));
                }
                v
            };
            // Apply each action under its target shard's lock only.
            // KEEP IN SYNC with `Coordinator::lbs_control`: the per-arm
            // semantics (Out → prime, In → gradual-drain no-op, Drop →
            // release_dag, ResetWindows → active+removed members) must
            // match the sim facade's — only the lock choreography may
            // differ between the drivers.
            for action in actions {
                match action {
                    crate::lbs::ScaleAction::Out {
                        dag,
                        sgs,
                        prime_target,
                        expected_rate,
                    } => {
                        let cell = &shared.shards[sgs.0 as usize];
                        let mut fx = Vec::new();
                        let mut done = Vec::new();
                        let mut st = cell.state.lock().unwrap();
                        st.shard.prime(now, dag, prime_target, expected_rate, &mut fx);
                        let remote =
                            drain_local(&mut st, now, &mut fx, &shared.registry, &mut done);
                        drop(st);
                        cell.cv.notify_all();
                        deliver(done);
                        apply_remote(&shared, now, remote);
                    }
                    crate::lbs::ScaleAction::In { .. } => {
                        // Gradual drain: the shard keeps serving
                        // discounted lottery traffic.
                    }
                    crate::lbs::ScaleAction::Drop { dag, sgs } => {
                        let cell = &shared.shards[sgs.0 as usize];
                        cell.state.lock().unwrap().shard.release_dag(dag);
                    }
                    crate::lbs::ScaleAction::ResetWindows { dag } => {
                        let members: Vec<SgsId> = {
                            let front = shared.front.lock().unwrap();
                            let mut m = front.lbs.active_sgs(dag).to_vec();
                            m.extend(front.lbs.removed_sgs(dag));
                            m
                        };
                        for sgs in members {
                            let cell = &shared.shards[sgs.0 as usize];
                            cell.state.lock().unwrap().shard.reset_qdelay_window(dag);
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;
    use crate::runtime::StubExecutorFactory;
    use std::path::PathBuf;

    fn stub_server(workers: usize, dags: Vec<DagSpec>, prewarm: &[&str]) -> Server {
        let factory = Arc::new(StubExecutorFactory::default());
        let opts = RtOptions {
            num_sgs: 1,
            workers,
            policy: SchedPolicy::Srsf,
            background_ticks: false,
            pool_mb: 4 * 1024,
        };
        Server::start_with(factory, dags, opts, prewarm, Manifest::empty()).unwrap()
    }

    #[test]
    fn stub_single_function_cold_then_warm() {
        let dag = DagSpec::single(DagId(0), "score", 5 * MS, 100 * MS, 128, 500 * MS);
        let server = stub_server(1, vec![dag], &[]);
        let c = server
            .submit("score", vec![1.0, 2.0], 500_000)
            .recv()
            .unwrap();
        assert!(c.cold, "first touch must be cold");
        assert_eq!(c.outputs[0].as_f32().unwrap(), &[3.0]);
        let c2 = server
            .submit("score", vec![4.0, 0.5], 500_000)
            .recv()
            .unwrap();
        assert!(!c2.cold, "sandbox reused on the same worker");
        assert_eq!(c2.setup_us, 0);
        assert_eq!(c2.outputs[0].as_f32().unwrap(), &[4.5]);
        assert_eq!(server.total_cold_starts(), 1);
        server.shutdown();
    }

    #[test]
    fn stub_prewarm_makes_first_hit_warm() {
        let dag = DagSpec::single(DagId(0), "score", 5 * MS, 100 * MS, 128, 500 * MS);
        let server = stub_server(2, vec![dag], &["score"]);
        let c = server.submit("score", vec![1.0], 500_000).recv().unwrap();
        assert!(!c.cold, "prewarmed artifact must be warm");
        assert_eq!(c.setup_us, 0);
        assert!(server.warm_counts().iter().all(|&n| n >= 1));
        server.shutdown();
    }

    #[test]
    fn unknown_artifact_drops_the_channel() {
        let dag = DagSpec::single(DagId(0), "score", 5 * MS, 100 * MS, 128, 500 * MS);
        let server = stub_server(1, vec![dag], &[]);
        assert!(server.submit("nope", vec![1.0], 500_000).recv().is_err());
        server.shutdown();
    }

    #[test]
    fn sharded_server_prewarms_every_shard() {
        let dags = vec![
            DagSpec::single(DagId(0), "score", 5 * MS, 100 * MS, 128, 500 * MS),
            DagSpec::single(DagId(1), "rank", 5 * MS, 100 * MS, 128, 500 * MS),
        ];
        let factory = Arc::new(StubExecutorFactory::default());
        let opts = RtOptions {
            num_sgs: 2,
            workers: 2,
            policy: SchedPolicy::Srsf,
            background_ticks: false,
            pool_mb: 4 * 1024,
        };
        let server =
            Server::start_with(factory, dags, opts, &["score"], Manifest::empty()).unwrap();
        // 2 shards × 2 workers, all prewarmed with one artifact
        let warm = server.warm_counts();
        assert_eq!(warm.len(), 4);
        assert!(warm.iter().all(|&n| n >= 1), "warm on every shard: {warm:?}");
        let c = server.submit("score", vec![1.0, 1.0], 500_000).recv().unwrap();
        assert!(!c.cold, "prewarm covers whichever shard routing picked");
        server.shutdown();
    }

    /// Forward every terminal result to an mpsc channel (test sink).
    struct ResultSink(Sender<RequestResult>);

    impl CompletionSink for ResultSink {
        fn complete(&self, r: RequestResult) {
            let _ = self.0.send(r);
        }
    }

    #[test]
    fn injected_executor_failure_delivers_explicit_failed_completion() {
        // Regression (ISSUE 4 satellite): a failed executor job used to
        // silently drop the reply channel, indistinguishable from a
        // crash. The sink path must deliver an explicit failure with
        // the error, and Metrics must count it.
        let dags = vec![
            DagSpec::single(DagId(0), "boom", 5 * MS, 20 * MS, 128, 500 * MS),
            DagSpec::single(DagId(1), "fine", 5 * MS, 20 * MS, 128, 500 * MS),
        ];
        let factory = Arc::new(StubExecutorFactory {
            fail_artifacts: ["boom".to_string()].into_iter().collect(),
            ..Default::default()
        });
        let opts = RtOptions {
            num_sgs: 1,
            workers: 1,
            policy: SchedPolicy::Srsf,
            background_ticks: false,
            pool_mb: 4 * 1024,
        };
        let server = Server::start_with(factory, dags, opts, &[], Manifest::empty()).unwrap();

        // Async path: the failure is explicit and carries the cause.
        let (tx, rx) = channel();
        let req = server
            .submit_dag_async(DagId(0), vec![1.0], 500_000, Arc::new(ResultSink(tx)))
            .expect("known DAG admits");
        match rx.recv().expect("exactly one terminal result") {
            RequestResult::Failed(f) => {
                assert_eq!(f.req, req);
                assert!(f.error.contains("boom"), "error names the cause: {}", f.error);
            }
            RequestResult::Done(c) => panic!("failed execution reported as done: {c:?}"),
        }

        // Blocking wrapper keeps its pre-sink contract: closed channel.
        assert!(server.submit_dag(DagId(0), vec![1.0], 500_000).recv().is_err());

        // Healthy DAGs still serve, and the metrics ledger shows two
        // failures whose timing-met credit was revoked.
        let c = server
            .submit_dag(DagId(1), vec![2.0, 2.0], 500_000)
            .recv()
            .expect("server survives failures");
        assert!(c.deadline_met);
        let row = server.summary();
        assert_eq!(row.completed, 3);
        assert_eq!(row.failed, 2);
        assert!(
            (row.deadline_met_rate - 1.0 / 3.0).abs() < 1e-9,
            "failed requests cannot count as met: {}",
            row.deadline_met_rate
        );
        server.shutdown();
    }

    #[test]
    fn unknown_dag_async_returns_none_without_touching_the_sink() {
        let dag = DagSpec::single(DagId(0), "score", 5 * MS, 100 * MS, 128, 500 * MS);
        let server = stub_server(1, vec![dag], &[]);
        let (tx, rx) = channel();
        assert!(server
            .submit_dag_async(DagId(99), vec![1.0], 500_000, Arc::new(ResultSink(tx)))
            .is_none());
        assert!(rx.recv().is_err(), "sink dropped uncalled: channel closes");
        server.shutdown();
    }

    // ---- PJRT-backed tests (skipped without `make artifacts`) ----

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn serve_warm_and_cold_jobs() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let server = Server::start(&dir, 2, SchedPolicy::Srsf, &["mlp_infer_b1"]).unwrap();
        // warm path
        let input: Vec<f32> = (0..256).map(|i| (i as f32 * 0.03).cos()).collect();
        let rx = server.submit("mlp_infer_b1", input.clone(), 100_000);
        let c = rx.recv().unwrap();
        assert!(!c.cold, "prewarmed artifact must be warm");
        assert_eq!(c.setup_us, 0);
        let probs = c.outputs[0].as_f32().unwrap();
        assert!((probs.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        // cold path: anomaly_score not prewarmed
        let input2: Vec<f32> = (0..128).map(|i| i as f32 * 0.05).collect();
        let rx2 = server.submit("anomaly_score_b1", input2, 500_000);
        let c2 = rx2.recv().unwrap();
        assert!(c2.cold);
        assert!(c2.setup_us > 0, "cold start must cost compile time");
        // second hit is warm: sandbox-aware dispatch reuses that worker
        let input3: Vec<f32> = (0..128).map(|i| i as f32 * 0.05).collect();
        let rx3 = server.submit("anomaly_score_b1", input3, 500_000);
        let c3 = rx3.recv().unwrap();
        assert!(!c3.cold, "sandbox-aware routing should reuse the warm worker");
        server.shutdown();
    }

    #[test]
    fn throughput_over_batch_of_requests() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let server = Server::start(&dir, 2, SchedPolicy::Srsf, &["mlp_infer_b1"]).unwrap();
        let input: Vec<f32> = vec![0.25; 256];
        let rxs: Vec<_> = (0..50)
            .map(|_| server.submit("mlp_infer_b1", input.clone(), 100_000))
            .collect();
        let mut cold = 0;
        for rx in rxs {
            let c = rx.recv().unwrap();
            if c.cold {
                cold += 1;
            }
            assert_eq!(c.outputs[0].as_f32().unwrap().len(), 10);
        }
        assert_eq!(cold, 0, "all prewarmed");
        server.shutdown();
    }
}
