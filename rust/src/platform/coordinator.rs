//! The driver-agnostic coordinator core (DESIGN.md §Coordinator).
//!
//! The paper's central claim is that one scheduling architecture
//! (LBS → SGS → worker pool, §3 Fig 3) serves both as a simulated
//! cluster and a real deployment. This module is that architecture with
//! time abstracted out: the request table, DAG fan-out on completion,
//! the warm-aware dispatch drain, and §6.1 failure re-routing all live
//! here, and every method takes `now` and appends [`Effect`]s to a
//! buffer instead of scheduling events or spawning work itself.
//!
//! A *driver* owns the clock and turns effects into its own notion of
//! time: the discrete-event engine ([`super::SimPlatform`]) maps
//! `Dispatched { dispatch.finish_at }` to a future `FnComplete` event,
//! while the wall-clock runtime ([`super::realtime`]) hands the same
//! effect to a worker thread and calls [`Coordinator::fn_complete`]
//! when the real execution returns. Both exercise the identical
//! scheduling code, so a policy change lands in one place.

use crate::config::{Config, Micros};
use crate::dag::{DagId, DagRegistry, FnId};
use crate::lbs::{Lbs, ScaleAction, SgsReport};
use crate::metrics::{Metrics, RequestOutcome};
use crate::sgs::{QueuedFn, RequestId, Sgs, SgsId};
use crate::util::fasthash::FastMap;
use crate::worker::WorkerId;

/// An instruction from the coordinator to its driver. Effects are
/// appended in a deterministic order; drivers must apply them in that
/// order (the discrete-event engine's determinism depends on it).
#[derive(Debug, Clone)]
pub enum Effect {
    /// Deliver `queued` to `sgs` at absolute time `at` (a routing hop:
    /// the LBS decision plus its network overhead).
    Enqueue {
        at: Micros,
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
    },
    /// A function started on `dispatch.worker`; in virtual time it
    /// finishes at `dispatch.finish_at`, in wall-clock time whenever the
    /// executor returns. `epoch` guards against completions from a
    /// worker that failed and was replaced mid-flight.
    Dispatched {
        sgs: SgsId,
        epoch: u64,
        dispatch: crate::sgs::Dispatch,
    },
    /// A proactive sandbox setup began; it becomes warm at
    /// `setup.done_at` (virtual) or when the executor finishes compiling
    /// (wall-clock), at which point the driver calls
    /// [`Coordinator::setup_done`].
    SetupStarted {
        sgs: SgsId,
        epoch: u64,
        setup: crate::sgs::SetupStart,
    },
    /// The whole request finished. Metrics were already recorded; the
    /// real-time driver uses this to reply to the caller.
    RequestDone {
        req: RequestId,
        outcome: RequestOutcome,
    },
}

/// Per-request in-flight bookkeeping (the request table).
#[derive(Debug)]
pub struct RequestState {
    pub dag: DagId,
    pub arrival: Micros,
    pub deadline_abs: Micros,
    /// Home SGS; downstream functions run here (§4.2 DAG awareness).
    pub sgs: SgsId,
    /// Outstanding parent count per function.
    pending_parents: Vec<u16>,
    /// Functions not yet completed.
    remaining: usize,
    pub cold_starts: u32,
    /// Sampled execution time per function for this request.
    exec_times: Vec<Micros>,
}

/// The platform-agnostic scheduling core: LBS + SGSs + request table.
pub struct Coordinator {
    pub cfg: Config,
    pub registry: DagRegistry,
    pub lbs: Lbs,
    pub sgss: Vec<Sgs>,
    pub metrics: Metrics,
    requests: FastMap<u64, RequestState>,
    next_req: u64,
    /// Completions before this time are excluded from metrics.
    warmup: Micros,
    /// Reused dispatch buffer (hot path, avoids per-event allocation).
    dispatch_buf: Vec<crate::sgs::Dispatch>,
}

impl Coordinator {
    /// Build the core over an already-populated DAG registry.
    pub fn new(cfg: Config, registry: DagRegistry, warmup: Micros, seed: u64) -> Self {
        cfg.validate().expect("invalid config");
        let sgss: Vec<Sgs> = (0..cfg.cluster.num_sgs)
            .map(|i| {
                Sgs::new(
                    SgsId(i as u16),
                    cfg.cluster.workers_per_sgs,
                    cfg.cluster.cores_per_worker,
                    cfg.cluster.proactive_pool_mb,
                    cfg.sgs.clone(),
                )
            })
            .collect();
        let lbs = Lbs::new(cfg.lbs.clone(), cfg.cluster.num_sgs, seed);
        Coordinator {
            registry,
            lbs,
            sgss,
            metrics: Metrics::new(),
            requests: FastMap::default(),
            next_req: 0,
            warmup,
            cfg,
            dispatch_buf: Vec::new(),
        }
    }

    /// Register every DAG in the registry with the LBS (bootstrap).
    pub fn register_all_dags(&mut self) {
        let ids: Vec<DagId> = self.registry.iter().map(|d| d.id).collect();
        for id in ids {
            self.lbs.register_dag(id);
        }
    }

    pub fn sgs(&self, id: SgsId) -> &Sgs {
        &self.sgss[id.0 as usize]
    }

    pub fn sgs_count(&self) -> usize {
        self.sgss.len()
    }

    pub fn total_cold_starts(&self) -> u64 {
        self.sgss.iter().map(|s| s.cold_starts()).sum()
    }

    /// Requests currently in flight.
    pub fn inflight(&self) -> usize {
        self.requests.len()
    }

    pub fn request(&self, req: RequestId) -> Option<&RequestState> {
        self.requests.get(&req.0)
    }

    /// Admit a new request for `dag_id`: allocate it in the request
    /// table, route it through the LBS, and emit `Enqueue` effects for
    /// the DAG's root functions after the routing overhead.
    ///
    /// `exec_times` holds the per-function execution-time estimates for
    /// this request (the simulator samples them with noise; the
    /// real-time driver passes the spec estimates). `deadline` overrides
    /// the DAG's default relative deadline when given (real-time callers
    /// carry per-request deadlines).
    pub fn admit(
        &mut self,
        now: Micros,
        dag_id: DagId,
        exec_times: Vec<Micros>,
        deadline: Option<Micros>,
        fx: &mut Vec<Effect>,
    ) -> RequestId {
        let dag = self.registry.get(dag_id);
        debug_assert_eq!(exec_times.len(), dag.len());
        let req_id = RequestId(self.next_req);
        self.next_req += 1;
        let mut state = RequestState {
            dag: dag_id,
            arrival: now,
            deadline_abs: now + deadline.unwrap_or(dag.deadline),
            sgs: SgsId(0), // set below
            pending_parents: dag.parent_count.clone(),
            remaining: dag.len(),
            cold_starts: 0,
            exec_times,
        };
        // Route (the paper's per-request LBS decision).
        let sgs = self.lbs.route(dag_id);
        state.sgs = sgs;
        // Enqueue the roots after the routing overhead.
        let enqueue_at = now + self.cfg.lbs.route_overhead;
        for &root in &self.registry.get(dag_id).roots {
            let queued = self.make_queued(&state, req_id, dag_id, root, enqueue_at);
            fx.push(Effect::Enqueue {
                at: enqueue_at,
                sgs,
                queued,
                is_root: true,
            });
        }
        self.requests.insert(req_id.0, state);
        req_id
    }

    fn make_queued(
        &self,
        state: &RequestState,
        req: RequestId,
        dag_id: DagId,
        fn_idx: u16,
        enqueued_at: Micros,
    ) -> QueuedFn {
        let dag = self.registry.get(dag_id);
        let spec = &dag.functions[fn_idx as usize];
        QueuedFn {
            req,
            f: dag.fn_id(fn_idx),
            dag: dag_id,
            enqueued_at,
            deadline_abs: state.deadline_abs,
            remaining_work: dag.cpl[fn_idx as usize],
            exec_time: state.exec_times[fn_idx as usize],
            setup_time: spec.setup_time,
            mem_mb: spec.mem_mb,
        }
    }

    /// A routed request (or a ready downstream function) reached its
    /// SGS: enqueue it and drain the dispatch loop. A dead SGS reroutes
    /// the function through the LBS (§6.1).
    pub fn enqueue(
        &mut self,
        now: Micros,
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
        fx: &mut Vec<Effect>,
    ) {
        let s = &mut self.sgss[sgs.0 as usize];
        if !s.is_alive() {
            // Failure between routing and enqueue: reroute through LBS.
            let dag = queued.dag;
            let alt = self.lbs.route(dag);
            if alt != sgs {
                fx.push(Effect::Enqueue {
                    at: now + self.cfg.lbs.route_overhead,
                    sgs: alt,
                    queued,
                    is_root,
                });
            }
            return;
        }
        s.enqueue(queued, is_root);
        self.dispatch(now, sgs, fx);
    }

    /// Run the SGS dispatch loop and emit `Dispatched` effects.
    fn dispatch(&mut self, now: Micros, sgs: SgsId, fx: &mut Vec<Effect>) {
        let s = &mut self.sgss[sgs.0 as usize];
        let mut dispatches = std::mem::take(&mut self.dispatch_buf);
        s.try_dispatch_into(now, &mut dispatches);
        for d in dispatches.drain(..) {
            let epoch = s.pool.get(d.worker).epoch();
            if now >= self.warmup {
                self.metrics.record_qdelay(d.f.dag, d.queue_delay);
            }
            if let Some(state) = self.requests.get_mut(&d.req.0) {
                state.cold_starts += u32::from(d.cold);
            }
            fx.push(Effect::Dispatched {
                sgs,
                epoch,
                dispatch: d,
            });
        }
        self.dispatch_buf = dispatches;
    }

    /// A dispatched function finished on a worker. Advances the
    /// request's DAG: emits `Enqueue` effects for ready children, a
    /// `RequestDone` effect when the sink completed, and new
    /// `Dispatched` effects for the freed core. A stale `epoch` (the
    /// worker failed while the function ran) re-enqueues the function
    /// instead (at-least-once semantics).
    #[allow(clippy::too_many_arguments)]
    pub fn fn_complete(
        &mut self,
        now: Micros,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        let s = &mut self.sgss[sgs.0 as usize];
        let current_epoch = s.pool.get(worker).epoch();
        if current_epoch != epoch || !s.pool.get(worker).is_alive() {
            // The worker died while this function ran: the execution is
            // lost; re-enqueue the function (at-least-once semantics).
            if self.requests.contains_key(&req.0) {
                let state = &self.requests[&req.0];
                let queued = self.make_queued(state, req, state.dag, f.idx, now);
                let target = state.sgs;
                fx.push(Effect::Enqueue {
                    at: now,
                    sgs: target,
                    queued,
                    is_root: false,
                });
            }
            return;
        }
        s.complete(worker, f, now);

        // Advance the request's DAG.
        let mut finished = false;
        let mut children_ready: Vec<u16> = Vec::new();
        if let Some(state) = self.requests.get_mut(&req.0) {
            state.remaining -= 1;
            finished = state.remaining == 0;
            let dag = self.registry.get(state.dag);
            for &c in &dag.children[f.idx as usize] {
                state.pending_parents[c as usize] -= 1;
                if state.pending_parents[c as usize] == 0 {
                    children_ready.push(c);
                }
            }
        }
        if finished {
            let state = self
                .requests
                .remove(&req.0)
                .expect("finished implies present");
            let outcome = RequestOutcome {
                dag: state.dag,
                arrival: state.arrival,
                completion: now,
                deadline_abs: state.deadline_abs,
                cold_starts: state.cold_starts,
            };
            if now >= self.warmup {
                self.metrics.record_completion(&outcome);
            }
            fx.push(Effect::RequestDone { req, outcome });
        } else if !children_ready.is_empty() {
            let state = &self.requests[&req.0];
            // Downstream functions run at the same SGS — §4.2: "As an SGS
            // is DAG aware, it schedules functions once their
            // dependencies are met."
            let target = state.sgs;
            for c in children_ready {
                let queued = self.make_queued(state, req, state.dag, c, now);
                fx.push(Effect::Enqueue {
                    at: now,
                    sgs: target,
                    queued,
                    is_root: false,
                });
            }
        }
        // The freed core may admit more queued work.
        self.dispatch(now, sgs, fx);
    }

    /// A proactive sandbox setup completed: the sandbox becomes warm and
    /// may convert a would-be-cold dispatch. Stale epochs are dropped
    /// (the sandbox was lost with the worker).
    pub fn setup_done(
        &mut self,
        now: Micros,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        let s = &mut self.sgss[sgs.0 as usize];
        if s.pool.get(worker).epoch() != epoch {
            return; // worker failed mid-setup; sandbox lost
        }
        s.setup_done(worker, f);
        self.dispatch(now, sgs, fx);
    }

    /// Periodic estimation at one SGS (§4.3.1): recompute demand,
    /// reconcile sandbox allocations (emitting `SetupStarted` effects),
    /// and piggyback per-DAG reports to the LBS (§5.2.1). A dead SGS is
    /// a no-op.
    pub fn estimator_tick(&mut self, now: Micros, sgs: SgsId, fx: &mut Vec<Effect>) {
        if !self.sgss[sgs.0 as usize].is_alive() {
            return;
        }
        let setups = {
            let s = &mut self.sgss[sgs.0 as usize];
            s.estimator_tick(now, &self.registry)
        };
        self.emit_setups(sgs, &setups, fx);
        let tracked = self.sgss[sgs.0 as usize].estimator.tracked();
        for dag_id in tracked {
            let s = &self.sgss[sgs.0 as usize];
            let dag = self.registry.get(dag_id);
            let report = SgsReport {
                sgs,
                sandboxes: s.dag_sandbox_count(dag),
                qdelay_us: s.estimator.qdelay(dag_id).unwrap_or(0.0),
                window_full: s.estimator.qdelay_window_full(dag_id),
            };
            self.lbs.update_report(dag_id, report);
        }
    }

    fn emit_setups(&mut self, sgs: SgsId, setups: &[crate::sgs::SetupStart], fx: &mut Vec<Effect>) {
        for su in setups {
            let epoch = self.sgss[sgs.0 as usize].pool.get(su.worker).epoch();
            fx.push(Effect::SetupStarted {
                sgs,
                epoch,
                setup: *su,
            });
        }
    }

    /// Periodic LBS scaling evaluation (§5.2, Pseudocode 2): apply the
    /// scale-out/in/drop actions, emitting `SetupStarted` effects for
    /// scale-out priming.
    pub fn lbs_control(&mut self, now: Micros, fx: &mut Vec<Effect>) {
        let dag_ids: Vec<DagId> = self.registry.iter().map(|d| d.id).collect();
        for dag_id in dag_ids {
            let slack = self.registry.get(dag_id).slack();
            let actions = self.lbs.control_tick(dag_id, slack);
            for action in actions {
                match action {
                    ScaleAction::Out {
                        dag,
                        sgs,
                        prime_target,
                        expected_rate,
                    } => {
                        let setups = self.sgss[sgs.0 as usize].prime_dag(
                            now,
                            dag,
                            prime_target,
                            expected_rate,
                            &self.registry,
                        );
                        self.emit_setups(sgs, &setups, fx);
                    }
                    ScaleAction::In { .. } => {
                        // Gradual drain: the SGS keeps serving discounted
                        // lottery traffic; its estimator decays demand.
                    }
                    ScaleAction::Drop { dag, sgs } => {
                        self.sgss[sgs.0 as usize].release_dag(dag, &self.registry);
                    }
                    ScaleAction::ResetWindows { dag } => {
                        let mut members: Vec<SgsId> = self.lbs.active_sgs(dag).to_vec();
                        members.extend(self.lbs.removed_sgs(dag));
                        for sgs in members {
                            self.sgss[sgs.0 as usize].estimator.reset_qdelay_window(dag);
                        }
                    }
                }
            }
        }
    }

    /// Fail-stop a worker (§6.1): in-flight completions on it will carry
    /// a stale epoch and be re-enqueued by [`Self::fn_complete`].
    pub fn fail_worker(&mut self, sgs: SgsId, worker: WorkerId) {
        self.sgss[sgs.0 as usize].fail_worker(worker);
    }

    pub fn recover_worker(&mut self, sgs: SgsId, worker: WorkerId) {
        self.sgss[sgs.0 as usize].recover_worker(worker);
    }

    /// Fail-stop an SGS (§6.1: state recovers from the external store;
    /// queued requests are re-routed through the LBS). Emits `Enqueue`
    /// effects for the orphaned queue contents.
    pub fn sgs_fail(&mut self, now: Micros, sgs: SgsId, fx: &mut Vec<Effect>) {
        let orphaned = self.sgss[sgs.0 as usize].fail();
        self.lbs.remove_sgs(sgs);
        for queued in orphaned {
            let dag = queued.dag;
            let alt = self.lbs.route(dag);
            // Requests whose home SGS died move entirely.
            if let Some(state) = self
                .requests
                .values_mut()
                .find(|r| r.sgs == sgs && r.dag == dag)
            {
                state.sgs = alt;
            }
            fx.push(Effect::Enqueue {
                at: now + self.cfg.lbs.route_overhead,
                sgs: alt,
                queued,
                is_root: false,
            });
        }
        // Reassign home SGS for all in-flight requests of the dead SGS.
        let reassign: Vec<u64> = self
            .requests
            .iter()
            .filter(|(_, r)| r.sgs == sgs)
            .map(|(id, _)| *id)
            .collect();
        for id in reassign {
            let dag = self.requests[&id].dag;
            let alt = self.lbs.route(dag);
            self.requests.get_mut(&id).unwrap().sgs = alt;
        }
    }

    /// Whole-platform structural invariants (driven by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        for s in &self.sgss {
            s.check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MS};
    use crate::dag::DagSpec;

    fn cfg(num_sgs: usize, workers: usize, cores: u32) -> Config {
        let mut cfg = Config::default();
        cfg.cluster = ClusterConfig {
            num_sgs,
            workers_per_sgs: workers,
            cores_per_worker: cores,
            worker_mem_mb: 16 * 1024,
            proactive_pool_mb: 8 * 1024,
        };
        cfg
    }

    fn chain_core() -> Coordinator {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::chain(
            DagId(0),
            "chain",
            &[(20 * MS, 150 * MS, 128), (30 * MS, 150 * MS, 128)],
            300 * MS,
        ));
        let mut core = Coordinator::new(cfg(1, 2, 4), registry, 0, 7);
        core.register_all_dags();
        core
    }

    /// Drive the core by hand, applying effects immediately: `Enqueue`
    /// recurses, `Dispatched` is collected for the caller to "complete".
    fn settle(core: &mut Coordinator, now: Micros, fx: &mut Vec<Effect>) -> Vec<Effect> {
        let mut out = Vec::new();
        while !fx.is_empty() {
            let batch: Vec<Effect> = std::mem::take(fx);
            for e in batch {
                match e {
                    Effect::Enqueue {
                        sgs,
                        queued,
                        is_root,
                        ..
                    } => core.enqueue(now, sgs, queued, is_root, fx),
                    other => out.push(other),
                }
            }
        }
        out
    }

    #[test]
    fn admit_runs_a_chain_dag_through_both_functions() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let exec: Vec<Micros> = vec![20 * MS, 30 * MS];
        let req = core.admit(0, DagId(0), exec, None, &mut fx);
        assert_eq!(core.inflight(), 1);
        let effects = settle(&mut core, 0, &mut fx);
        // one root dispatched, cold
        let (sgs, epoch, d0) = match &effects[..] {
            [Effect::Dispatched {
                sgs,
                epoch,
                dispatch,
            }] => (*sgs, *epoch, dispatch.clone()),
            other => panic!("expected one dispatch, got {other:?}"),
        };
        assert_eq!(d0.req, req);
        assert!(d0.cold);
        // complete fn 0: fn 1 becomes ready and dispatches
        core.fn_complete(d0.finish_at, sgs, d0.worker, epoch, req, d0.f, &mut fx);
        let effects = settle(&mut core, d0.finish_at, &mut fx);
        let d1 = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { dispatch, .. } => Some(dispatch.clone()),
                _ => None,
            })
            .expect("child dispatched");
        assert_eq!(d1.f.idx, 1);
        // complete fn 1: the request finishes
        core.fn_complete(d1.finish_at, sgs, d1.worker, epoch, req, d1.f, &mut fx);
        let effects = settle(&mut core, d1.finish_at, &mut fx);
        let done = effects.iter().any(|e| matches!(e, Effect::RequestDone { req: r, .. } if *r == req));
        assert!(done, "expected RequestDone, got {effects:?}");
        assert_eq!(core.inflight(), 0);
        assert_eq!(core.metrics.total.completed, 1);
        core.check_invariants().unwrap();
    }

    #[test]
    fn deadline_override_applies_per_request() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let req = core.admit(1000, DagId(0), vec![20 * MS, 30 * MS], Some(70 * MS), &mut fx);
        assert_eq!(core.request(req).unwrap().deadline_abs, 1000 + 70 * MS);
        let req2 = core.admit(1000, DagId(0), vec![20 * MS, 30 * MS], None, &mut fx);
        assert_eq!(core.request(req2).unwrap().deadline_abs, 1000 + 300 * MS);
    }

    #[test]
    fn stale_epoch_completion_reenqueues_instead_of_advancing() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let req = core.admit(0, DagId(0), vec![20 * MS, 30 * MS], None, &mut fx);
        let effects = settle(&mut core, 0, &mut fx);
        let (sgs, d0) = match &effects[..] {
            [Effect::Dispatched { sgs, dispatch, .. }] => (*sgs, dispatch.clone()),
            other => panic!("{other:?}"),
        };
        // the worker fails while fn 0 runs
        core.fail_worker(sgs, d0.worker);
        core.recover_worker(sgs, d0.worker);
        core.fn_complete(d0.finish_at, sgs, d0.worker, 0, req, d0.f, &mut fx);
        let effects = settle(&mut core, d0.finish_at, &mut fx);
        // the lost execution was re-enqueued and re-dispatched, still fn 0
        let redisp = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { dispatch, .. } => Some(dispatch.clone()),
                _ => None,
            })
            .expect("re-dispatch after lost execution");
        assert_eq!(redisp.f.idx, 0);
        assert_eq!(core.inflight(), 1, "request still in flight");
    }

    #[test]
    fn sgs_failure_reroutes_queued_work() {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::single(DagId(0), "t", 50 * MS, 200 * MS, 128, 200 * MS));
        let mut core = Coordinator::new(cfg(2, 1, 1), registry, 0, 7);
        core.register_all_dags();
        let mut fx = Vec::new();
        // saturate the single core of whichever SGS routing picks, then
        // queue two more requests behind it
        for _ in 0..3 {
            core.admit(0, DagId(0), vec![50 * MS], None, &mut fx);
        }
        let effects = settle(&mut core, 0, &mut fx);
        let sgs = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { sgs, .. } => Some(*sgs),
                _ => None,
            })
            .expect("at least one dispatch");
        let queued_before = core.sgs(sgs).queue.len();
        assert!(queued_before > 0, "some requests must be queued");
        core.sgs_fail(0, sgs, &mut fx);
        // orphaned entries come back as Enqueue effects to the other SGS
        let mut reroutes = 0;
        for e in &*fx {
            if let Effect::Enqueue { sgs: alt, .. } = e {
                assert_ne!(*alt, sgs, "rerouted to the dead SGS");
                reroutes += 1;
            }
        }
        assert_eq!(reroutes, queued_before);
    }
}
