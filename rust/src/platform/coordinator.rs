//! The driver-agnostic coordinator core, sharded (DESIGN.md §Coordinator,
//! §Sharding).
//!
//! The paper's central claim is that one scheduling architecture
//! (LBS → SGS → worker pool, §3 Fig 3) serves both as a simulated
//! cluster and a real deployment — and that each SGS schedules its
//! worker pool *independently* (§5). The core mirrors that split:
//!
//! * [`Front`] — the routing front-end: LBS, DAG registry, request-ID
//!   allocation, and admission. It never touches a worker pool.
//! * [`Shard`] — one SGS plus everything whose lifetime is tied to it:
//!   the request states routed there, a per-shard [`Metrics`] (merged on
//!   read), and the dispatch loop.
//!
//! Neither owns a clock or a thread: every method takes `now` and
//! appends [`Effect`]s to a buffer. Cross-shard work — downstream
//! fan-out after a migration, §6.1 failure re-routing — travels as
//! effects too ([`Effect::Reroute`], [`Effect::Advance`]), so a driver
//! can hold at most one shard's state at a time. The wall-clock driver
//! ([`super::realtime`]) exploits exactly that: one mutex per shard, a
//! short-critical-section lock on the front, admits to different SGSs
//! running fully in parallel. The discrete-event driver
//! ([`super::SimPlatform`]) goes through the single-threaded
//! [`Coordinator`] facade, which applies effects in the pre-shard push
//! order so simulation results stay bit-identical by construction;
//! `rust/tests/golden_sim.rs` pins that behavior for every refactor
//! after the snapshot is first committed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::config::{Config, Micros};
use crate::dag::{DagId, DagRegistry, FnId};
use crate::lbs::{Lbs, ScaleAction, SgsReport};
use crate::metrics::{Metrics, RequestOutcome};
use crate::sgs::{QueuedFn, RequestId, Sgs, SgsId};
use crate::util::fasthash::FastMap;
use crate::worker::WorkerId;

/// An instruction from the core to its driver. Effects are appended in
/// a deterministic order; drivers must apply them in that order (the
/// discrete-event engine's determinism depends on it).
#[derive(Debug, Clone)]
pub enum Effect {
    /// Deliver `queued` to `sgs` at absolute time `at` (a routing hop:
    /// the LBS decision plus its network overhead).
    Enqueue {
        at: Micros,
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
    },
    /// A function started on `dispatch.worker`; in virtual time it
    /// finishes at `dispatch.finish_at`, in wall-clock time whenever the
    /// executor returns. `epoch` guards against completions from a
    /// worker that failed and was replaced mid-flight.
    Dispatched {
        sgs: SgsId,
        epoch: u64,
        dispatch: crate::sgs::Dispatch,
    },
    /// A proactive sandbox setup began; it becomes warm at
    /// `setup.done_at` (virtual) or when the executor finishes compiling
    /// (wall-clock), at which point the driver calls
    /// [`Shard::setup_done`].
    SetupStarted {
        sgs: SgsId,
        epoch: u64,
        setup: crate::sgs::SetupStart,
    },
    /// The whole request finished. Metrics were already recorded; the
    /// real-time driver uses this to reply to the caller.
    RequestDone {
        req: RequestId,
        outcome: RequestOutcome,
    },
    /// A shard refused `queued` (its SGS is fail-stopped): the front
    /// must pick a live SGS (§6.1). Resolved by [`Front::reroute`] into
    /// an `Enqueue` after the routing overhead.
    Reroute {
        from: SgsId,
        queued: QueuedFn,
        is_root: bool,
    },
    /// A function completion arrived at a shard whose request state has
    /// migrated (§6.1 SGS failure): forward the DAG advancement to the
    /// request's new home shard. `lost` marks a stale-epoch completion
    /// whose execution must be re-enqueued instead.
    Advance {
        sgs: SgsId,
        req: RequestId,
        f: FnId,
        lost: bool,
    },
}

/// Per-request in-flight bookkeeping (one entry of a shard's request
/// table).
#[derive(Debug)]
pub struct RequestState {
    pub dag: DagId,
    pub arrival: Micros,
    pub deadline_abs: Micros,
    /// Home SGS; downstream functions run here (§4.2 DAG awareness).
    pub sgs: SgsId,
    /// Outstanding parent count per function.
    pending_parents: Vec<u16>,
    /// Functions not yet completed.
    remaining: usize,
    pub cold_starts: u32,
    /// Sampled execution time per function for this request.
    exec_times: Vec<Micros>,
}

/// Build the queue entry for one runnable function of a request.
fn make_queued(
    registry: &DagRegistry,
    state: &RequestState,
    req: RequestId,
    dag_id: DagId,
    fn_idx: u16,
    enqueued_at: Micros,
) -> QueuedFn {
    let dag = registry.get(dag_id);
    let spec = &dag.functions[fn_idx as usize];
    QueuedFn {
        req,
        f: dag.fn_id(fn_idx),
        dag: dag_id,
        enqueued_at,
        deadline_abs: state.deadline_abs,
        remaining_work: dag.cpl[fn_idx as usize],
        exec_time: state.exec_times[fn_idx as usize],
        setup_time: spec.setup_time,
        mem_mb: spec.mem_mb,
    }
}

/// The routing front-end: LBS + DAG registry + request-ID allocation +
/// admission. Holds no per-SGS state, so its critical sections are a
/// route draw and a handful of pushes — the wall-clock driver keeps it
/// behind its own short lock while shards run in parallel.
pub struct Front {
    pub cfg: Config,
    pub registry: Arc<DagRegistry>,
    pub lbs: Lbs,
    /// Globally unique request ids; atomic so allocation never needs the
    /// routing lock.
    next_req: AtomicU64,
}

impl Front {
    pub fn new(cfg: Config, registry: Arc<DagRegistry>, seed: u64) -> Self {
        let lbs = Lbs::new(cfg.lbs.clone(), cfg.cluster.num_sgs, seed);
        Front {
            cfg,
            registry,
            lbs,
            next_req: AtomicU64::new(0),
        }
    }

    /// Register every DAG in the registry with the LBS (bootstrap).
    pub fn register_all_dags(&mut self) {
        let ids: Vec<DagId> = self.registry.iter().map(|d| d.id).collect();
        for id in ids {
            self.lbs.register_dag(id);
        }
    }

    /// Admit a new request for `dag_id`: allocate its id, route it
    /// through the LBS, and emit `Enqueue` effects for the DAG's root
    /// functions after the routing overhead. Returns the request state
    /// for the caller to install on the home shard (the front never
    /// touches shard tables), or `None` when the DAG is unknown.
    ///
    /// `exec_times` holds the per-function execution-time estimates for
    /// this request (the simulator samples them with noise; the
    /// real-time driver passes the spec estimates). `deadline` overrides
    /// the DAG's default relative deadline when given (real-time callers
    /// carry per-request deadlines).
    pub fn admit(
        &mut self,
        now: Micros,
        dag_id: DagId,
        exec_times: Vec<Micros>,
        deadline: Option<Micros>,
        fx: &mut Vec<Effect>,
    ) -> Option<(RequestId, SgsId, RequestState)> {
        let dag = self.registry.try_get(dag_id)?;
        debug_assert_eq!(exec_times.len(), dag.len());
        let req_id = RequestId(self.next_req.fetch_add(1, Ordering::Relaxed));
        let mut state = RequestState {
            dag: dag_id,
            arrival: now,
            deadline_abs: now + deadline.unwrap_or(dag.deadline),
            sgs: SgsId(0), // set below
            pending_parents: dag.parent_count.clone(),
            remaining: dag.len(),
            cold_starts: 0,
            exec_times,
        };
        // Route (the paper's per-request LBS decision).
        let sgs = self.lbs.route(dag_id);
        state.sgs = sgs;
        // Enqueue the roots after the routing overhead.
        let enqueue_at = now + self.cfg.lbs.route_overhead;
        for &root in &self.registry.get(dag_id).roots {
            let queued = make_queued(&self.registry, &state, req_id, dag_id, root, enqueue_at);
            fx.push(Effect::Enqueue {
                at: enqueue_at,
                sgs,
                queued,
                is_root: true,
            });
        }
        Some((req_id, sgs, state))
    }

    /// Resolve a [`Effect::Reroute`]: pick a live SGS for a function a
    /// dead shard refused (§6.1). Dropped when routing lands back on the
    /// refusing SGS (no live alternative yet).
    pub fn reroute(
        &mut self,
        now: Micros,
        from: SgsId,
        queued: QueuedFn,
        is_root: bool,
        fx: &mut Vec<Effect>,
    ) {
        let alt = self.lbs.route(queued.dag);
        if alt != from {
            fx.push(Effect::Enqueue {
                at: now + self.cfg.lbs.route_overhead,
                sgs: alt,
                queued,
                is_root,
            });
        }
    }
}

/// One coordinator shard: an SGS, the request states homed there, and a
/// private [`Metrics`] — everything a scheduling decision for this SGS
/// needs, so a driver can protect each shard with its own lock.
pub struct Shard {
    pub sgs: Sgs,
    pub metrics: Metrics,
    registry: Arc<DagRegistry>,
    /// Requests whose home SGS is this shard.
    requests: FastMap<u64, RequestState>,
    /// Forwarding addresses for requests migrated away at SGS failure
    /// (§6.1): straggler completions chase the state via
    /// [`Effect::Advance`].
    moved: FastMap<u64, SgsId>,
    /// Completions before this time are excluded from metrics.
    warmup: Micros,
    /// Reused dispatch buffer (hot path, avoids per-event allocation).
    dispatch_buf: Vec<crate::sgs::Dispatch>,
}

impl Shard {
    pub fn new(sgs: Sgs, registry: Arc<DagRegistry>, warmup: Micros) -> Self {
        Shard {
            sgs,
            metrics: Metrics::new(),
            registry,
            requests: FastMap::default(),
            moved: FastMap::default(),
            warmup,
            dispatch_buf: Vec::new(),
        }
    }

    pub fn id(&self) -> SgsId {
        self.sgs.id
    }

    /// Requests currently homed on this shard.
    pub fn inflight(&self) -> usize {
        self.requests.len()
    }

    pub fn request(&self, req: RequestId) -> Option<&RequestState> {
        self.requests.get(&req.0)
    }

    /// Install an admitted (or migrated) request's state. Must happen
    /// before the driver applies the request's `Enqueue` effects.
    pub fn install(&mut self, req: RequestId, state: RequestState) {
        self.moved.remove(&req.0);
        self.requests.insert(req.0, state);
    }

    /// A routed request (or a ready downstream function) reached this
    /// shard: enqueue it and drain the dispatch loop. A dead SGS
    /// forwards the function to the request's migrated home when it
    /// knows one (keeping queued work and request state co-located), or
    /// emits a `Reroute` for the front otherwise (§6.1).
    pub fn enqueue(&mut self, now: Micros, queued: QueuedFn, is_root: bool, fx: &mut Vec<Effect>) {
        if !self.sgs.is_alive() {
            if let Some(&home) = self.moved.get(&queued.req.0) {
                fx.push(Effect::Enqueue {
                    at: now,
                    sgs: home,
                    queued,
                    is_root,
                });
            } else {
                fx.push(Effect::Reroute {
                    from: self.sgs.id,
                    queued,
                    is_root,
                });
            }
            return;
        }
        self.sgs.enqueue(queued, is_root);
        self.dispatch(now, fx);
    }

    /// Run the SGS dispatch loop and emit `Dispatched` effects.
    fn dispatch(&mut self, now: Micros, fx: &mut Vec<Effect>) {
        let mut dispatches = std::mem::take(&mut self.dispatch_buf);
        self.sgs.try_dispatch_into(now, &mut dispatches);
        let sgs = self.sgs.id;
        for d in dispatches.drain(..) {
            let epoch = self.sgs.pool.get(d.worker).epoch();
            if now >= self.warmup {
                self.metrics.record_qdelay(d.f.dag, d.queue_delay);
            }
            if let Some(state) = self.requests.get_mut(&d.req.0) {
                state.cold_starts += u32::from(d.cold);
            }
            fx.push(Effect::Dispatched {
                sgs,
                epoch,
                dispatch: d,
            });
        }
        self.dispatch_buf = dispatches;
    }

    /// A dispatched function finished on a worker of this shard. Frees
    /// the core, then advances the request's DAG ([`Self::advance`]) —
    /// inline when the request is homed here, as an [`Effect::Advance`]
    /// when its state migrated at an SGS failure. A stale `epoch` (the
    /// worker failed while the function ran) re-enqueues the function
    /// instead (at-least-once semantics).
    pub fn fn_complete(
        &mut self,
        now: Micros,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        let w = self.sgs.pool.get(worker);
        if w.epoch() != epoch || !w.is_alive() {
            // The worker died while this function ran: the execution is
            // lost; re-enqueue the function (at-least-once semantics).
            self.advance_or_forward(now, req, f, true, fx);
            return;
        }
        self.sgs.complete(worker, f, now);
        self.advance_or_forward(now, req, f, false, fx);
        // The freed core may admit more queued work.
        self.dispatch(now, fx);
    }

    fn advance_or_forward(
        &mut self,
        now: Micros,
        req: RequestId,
        f: FnId,
        lost: bool,
        fx: &mut Vec<Effect>,
    ) {
        if self.requests.contains_key(&req.0) {
            self.advance(now, req, f, lost, fx);
        } else if let Some(&home) = self.moved.get(&req.0) {
            fx.push(Effect::Advance {
                sgs: home,
                req,
                f,
                lost,
            });
        }
        // else: the request already finished (duplicate completion after
        // an at-least-once re-execution) — nothing to advance.
    }

    /// Advance `req`'s DAG after `f` completed: emit `Enqueue` effects
    /// for ready children, a `RequestDone` effect when the sink
    /// completed. With `lost`, re-enqueue `f` instead (the execution
    /// died with its worker). Re-forwards when the state has migrated
    /// again.
    pub fn advance(
        &mut self,
        now: Micros,
        req: RequestId,
        f: FnId,
        lost: bool,
        fx: &mut Vec<Effect>,
    ) {
        if !self.requests.contains_key(&req.0) {
            if let Some(&home) = self.moved.get(&req.0) {
                fx.push(Effect::Advance {
                    sgs: home,
                    req,
                    f,
                    lost,
                });
            }
            return;
        }
        if lost {
            let state = &self.requests[&req.0];
            let queued = make_queued(&self.registry, state, req, state.dag, f.idx, now);
            fx.push(Effect::Enqueue {
                at: now,
                sgs: state.sgs,
                queued,
                is_root: false,
            });
            return;
        }
        let mut finished = false;
        let mut children_ready: Vec<u16> = Vec::new();
        if let Some(state) = self.requests.get_mut(&req.0) {
            state.remaining -= 1;
            finished = state.remaining == 0;
            let dag = self.registry.get(state.dag);
            for &c in &dag.children[f.idx as usize] {
                state.pending_parents[c as usize] -= 1;
                if state.pending_parents[c as usize] == 0 {
                    children_ready.push(c);
                }
            }
        }
        if finished {
            let state = self
                .requests
                .remove(&req.0)
                .expect("finished implies present");
            let outcome = RequestOutcome {
                dag: state.dag,
                arrival: state.arrival,
                completion: now,
                deadline_abs: state.deadline_abs,
                cold_starts: state.cold_starts,
            };
            if now >= self.warmup {
                self.metrics.record_completion(&outcome);
            }
            fx.push(Effect::RequestDone { req, outcome });
        } else if !children_ready.is_empty() {
            let state = &self.requests[&req.0];
            // Downstream functions run at the same SGS — §4.2: "As an SGS
            // is DAG aware, it schedules functions once their
            // dependencies are met."
            let target = state.sgs;
            for c in children_ready {
                let queued = make_queued(&self.registry, state, req, state.dag, c, now);
                fx.push(Effect::Enqueue {
                    at: now,
                    sgs: target,
                    queued,
                    is_root: false,
                });
            }
        }
    }

    /// A proactive sandbox setup completed: the sandbox becomes warm and
    /// may convert a would-be-cold dispatch. Stale epochs are dropped
    /// (the sandbox was lost with the worker).
    pub fn setup_done(
        &mut self,
        now: Micros,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        if self.sgs.pool.get(worker).epoch() != epoch {
            return; // worker failed mid-setup; sandbox lost
        }
        self.sgs.setup_done(worker, f);
        self.dispatch(now, fx);
    }

    /// Periodic estimation (§4.3.1): recompute demand, reconcile sandbox
    /// allocations (emitting `SetupStarted` effects), and return the
    /// per-DAG reports to piggyback to the LBS (§5.2.1) — the caller
    /// forwards them to the front, so the shard never needs its lock. A
    /// dead SGS is a no-op.
    pub fn estimator_tick(&mut self, now: Micros, fx: &mut Vec<Effect>) -> Vec<(DagId, SgsReport)> {
        if !self.sgs.is_alive() {
            return Vec::new();
        }
        let setups = self.sgs.estimator_tick(now, &self.registry);
        self.emit_setups(&setups, fx);
        let mut reports = Vec::new();
        for dag_id in self.sgs.estimator.tracked() {
            let dag = self.registry.get(dag_id);
            let report = SgsReport {
                sgs: self.sgs.id,
                sandboxes: self.sgs.dag_sandbox_count(dag),
                qdelay_us: self.sgs.estimator.qdelay(dag_id).unwrap_or(0.0),
                window_full: self.sgs.estimator.qdelay_window_full(dag_id),
            };
            reports.push((dag_id, report));
        }
        reports
    }

    fn emit_setups(&self, setups: &[crate::sgs::SetupStart], fx: &mut Vec<Effect>) {
        for su in setups {
            let epoch = self.sgs.pool.get(su.worker).epoch();
            fx.push(Effect::SetupStarted {
                sgs: self.sgs.id,
                epoch,
                setup: *su,
            });
        }
    }

    /// LBS scale-out priming on this shard (§5.2.3).
    pub fn prime(
        &mut self,
        now: Micros,
        dag: DagId,
        prime_target: u32,
        expected_rate: f64,
        fx: &mut Vec<Effect>,
    ) {
        let setups = self
            .sgs
            .prime_dag(now, dag, prime_target, expected_rate, &self.registry);
        self.emit_setups(&setups, fx);
    }

    /// Fully dissociate a drained DAG (post scale-in).
    pub fn release_dag(&mut self, dag: DagId) {
        self.sgs.release_dag(dag, &self.registry);
    }

    pub fn reset_qdelay_window(&mut self, dag: DagId) {
        self.sgs.estimator.reset_qdelay_window(dag);
    }

    pub fn fail_worker(&mut self, worker: WorkerId) {
        self.sgs.fail_worker(worker);
    }

    pub fn recover_worker(&mut self, worker: WorkerId) {
        self.sgs.recover_worker(worker);
    }

    /// Fail-stop this shard's SGS; queue contents are returned for
    /// re-routing by the caller (§6.1).
    pub fn fail(&mut self) -> Vec<QueuedFn> {
        self.sgs.fail()
    }

    /// Migration support (§6.1): detach one in-flight request so the
    /// caller can re-home it.
    fn remove_request(&mut self, req: RequestId) -> Option<RequestState> {
        self.requests.remove(&req.0)
    }

    /// Where a migrated request now lives, if this shard forwarded it.
    fn forwarded(&self, req: RequestId) -> Option<SgsId> {
        self.moved.get(&req.0).copied()
    }

    /// Migration support (§6.1): detach every in-flight request.
    fn drain_requests(&mut self) -> Vec<(u64, RequestState)> {
        self.requests.drain().collect()
    }

    /// Record a forwarding address for a migrated request.
    fn note_moved(&mut self, id: u64, to: SgsId) {
        self.moved.insert(id, to);
    }

    pub fn check_invariants(&self) -> Result<(), String> {
        self.sgs.check_invariants()
    }
}

/// Single-threaded facade over [`Front`] + [`Shard`]s: the API the
/// discrete-event driver (and the unit tests) program against. It
/// resolves cross-shard effects (`Reroute`, `Advance`) inline, splicing
/// their expansions at the position the pre-shard coordinator pushed
/// the equivalent effects — so the effect stream, and with it the
/// golden simulation snapshot, is bit-identical to the unsharded code.
pub struct Coordinator {
    pub front: Front,
    pub shards: Vec<Shard>,
}

impl Coordinator {
    /// Build the core over an already-populated DAG registry.
    pub fn new(cfg: Config, registry: DagRegistry, warmup: Micros, seed: u64) -> Self {
        cfg.validate().expect("invalid config");
        let registry = Arc::new(registry);
        let shards: Vec<Shard> = (0..cfg.cluster.num_sgs)
            .map(|i| {
                let sgs = Sgs::new(
                    SgsId(i as u16),
                    cfg.cluster.workers_per_sgs,
                    cfg.cluster.cores_per_worker,
                    cfg.cluster.proactive_pool_mb,
                    cfg.sgs.clone(),
                );
                Shard::new(sgs, Arc::clone(&registry), warmup)
            })
            .collect();
        let front = Front::new(cfg, registry, seed);
        Coordinator { front, shards }
    }

    /// Register every DAG in the registry with the LBS (bootstrap).
    pub fn register_all_dags(&mut self) {
        self.front.register_all_dags();
    }

    pub fn cfg(&self) -> &Config {
        &self.front.cfg
    }

    pub fn registry(&self) -> &DagRegistry {
        &self.front.registry
    }

    pub fn lbs(&self) -> &Lbs {
        &self.front.lbs
    }

    pub fn sgs(&self, id: SgsId) -> &Sgs {
        &self.shards[id.0 as usize].sgs
    }

    pub fn sgs_count(&self) -> usize {
        self.shards.len()
    }

    pub fn total_cold_starts(&self) -> u64 {
        self.shards.iter().map(|s| s.sgs.cold_starts()).sum()
    }

    /// Requests currently in flight (across all shards).
    pub fn inflight(&self) -> usize {
        self.shards.iter().map(|s| s.inflight()).sum()
    }

    pub fn request(&self, req: RequestId) -> Option<&RequestState> {
        self.shards.iter().find_map(|s| s.request(req))
    }

    /// Merge every shard's metrics into one run-wide view (read path;
    /// shards record independently).
    pub fn merged_metrics(&self) -> Metrics {
        let mut m = Metrics::new();
        for s in &self.shards {
            m.merge(&s.metrics);
        }
        m
    }

    /// Admit a new request: front allocates + routes, the home shard
    /// gets the request state installed. See [`Front::admit`].
    pub fn admit(
        &mut self,
        now: Micros,
        dag_id: DagId,
        exec_times: Vec<Micros>,
        deadline: Option<Micros>,
        fx: &mut Vec<Effect>,
    ) -> RequestId {
        let (req, sgs, state) = self
            .front
            .admit(now, dag_id, exec_times, deadline, fx)
            .expect("admit: unknown dag");
        self.shards[sgs.0 as usize].install(req, state);
        req
    }

    /// Deliver a routed function to its SGS. See [`Shard::enqueue`].
    pub fn enqueue(
        &mut self,
        now: Micros,
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
        fx: &mut Vec<Effect>,
    ) {
        let base = fx.len();
        self.shards[sgs.0 as usize].enqueue(now, queued, is_root, fx);
        self.resolve(now, base, fx);
    }

    /// A dispatched function finished. See [`Shard::fn_complete`].
    #[allow(clippy::too_many_arguments)]
    pub fn fn_complete(
        &mut self,
        now: Micros,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        let base = fx.len();
        self.shards[sgs.0 as usize].fn_complete(now, worker, epoch, req, f, fx);
        self.resolve(now, base, fx);
    }

    /// A proactive sandbox setup completed. See [`Shard::setup_done`].
    pub fn setup_done(
        &mut self,
        now: Micros,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
        fx: &mut Vec<Effect>,
    ) {
        self.shards[sgs.0 as usize].setup_done(now, worker, epoch, f, fx);
    }

    /// Periodic estimation at one SGS (§4.3.1), piggybacking the shard's
    /// reports to the LBS (§5.2.1).
    pub fn estimator_tick(&mut self, now: Micros, sgs: SgsId, fx: &mut Vec<Effect>) {
        let reports = self.shards[sgs.0 as usize].estimator_tick(now, fx);
        for (dag_id, report) in reports {
            self.front.lbs.update_report(dag_id, report);
        }
    }

    /// Periodic LBS scaling evaluation (§5.2, Pseudocode 2): apply the
    /// scale-out/in/drop actions to the shards they target. KEEP IN
    /// SYNC with the realtime ticker's action loop (`ticker_main` in
    /// `realtime.rs`), which applies the same per-arm semantics under
    /// per-shard locks.
    pub fn lbs_control(&mut self, now: Micros, fx: &mut Vec<Effect>) {
        let dag_ids: Vec<DagId> = self.front.registry.iter().map(|d| d.id).collect();
        for dag_id in dag_ids {
            let slack = self.front.registry.get(dag_id).slack();
            let actions = self.front.lbs.control_tick(dag_id, slack);
            for action in actions {
                match action {
                    ScaleAction::Out {
                        dag,
                        sgs,
                        prime_target,
                        expected_rate,
                    } => {
                        let shard = &mut self.shards[sgs.0 as usize];
                        shard.prime(now, dag, prime_target, expected_rate, fx);
                    }
                    ScaleAction::In { .. } => {
                        // Gradual drain: the SGS keeps serving discounted
                        // lottery traffic; its estimator decays demand.
                    }
                    ScaleAction::Drop { dag, sgs } => {
                        self.shards[sgs.0 as usize].release_dag(dag);
                    }
                    ScaleAction::ResetWindows { dag } => {
                        let mut members: Vec<SgsId> = self.front.lbs.active_sgs(dag).to_vec();
                        members.extend(self.front.lbs.removed_sgs(dag));
                        for sgs in members {
                            self.shards[sgs.0 as usize].reset_qdelay_window(dag);
                        }
                    }
                }
            }
        }
    }

    /// Fail-stop a worker (§6.1): in-flight completions on it will carry
    /// a stale epoch and be re-enqueued by [`Shard::fn_complete`].
    pub fn fail_worker(&mut self, sgs: SgsId, worker: WorkerId) {
        self.shards[sgs.0 as usize].fail_worker(worker);
    }

    pub fn recover_worker(&mut self, sgs: SgsId, worker: WorkerId) {
        self.shards[sgs.0 as usize].recover_worker(worker);
    }

    /// Fail-stop an SGS (§6.1: state recovers from the external store;
    /// queued requests are re-routed through the LBS). Emits `Enqueue`
    /// effects for the orphaned queue contents and migrates the dead
    /// shard's request states to their new home shards, leaving
    /// forwarding addresses for straggler completions.
    pub fn sgs_fail(&mut self, now: Micros, sgs: SgsId, fx: &mut Vec<Effect>) {
        let s = sgs.0 as usize;
        let orphaned = self.shards[s].fail();
        self.front.lbs.remove_sgs(sgs);
        // Re-route each orphaned queue entry, migrating its request's
        // state with it — a queued function and its request table entry
        // must stay co-located (the shard locality invariant; with the
        // old global request table any live SGS could advance any
        // request, so the pre-shard code could scatter them).
        for queued in orphaned {
            let target = match self.shards[s].forwarded(queued.req) {
                Some(home) => home, // a sibling entry already moved it
                None => {
                    let alt = self.front.lbs.route(queued.dag);
                    if let Some(mut state) = self.shards[s].remove_request(queued.req) {
                        state.sgs = alt;
                        self.shards[s].note_moved(queued.req.0, alt);
                        self.shards[alt.0 as usize].install(queued.req, state);
                    }
                    alt
                }
            };
            fx.push(Effect::Enqueue {
                at: now + self.front.cfg.lbs.route_overhead,
                sgs: target,
                queued,
                is_root: false,
            });
        }
        // Re-home every remaining in-flight request of the dead SGS.
        for (id, mut state) in self.shards[s].drain_requests() {
            let alt = self.front.lbs.route(state.dag);
            state.sgs = alt;
            self.shards[s].note_moved(id, alt);
            self.shards[alt.0 as usize].install(RequestId(id), state);
        }
    }

    /// Whole-platform structural invariants (driven by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        for s in &self.shards {
            s.check_invariants()?;
        }
        Ok(())
    }

    /// Expand cross-shard effects (`Reroute`, `Advance`) in place,
    /// starting at index `base`. The expansion is spliced at the
    /// position of the effect it replaces — exactly where the unsharded
    /// coordinator pushed the equivalent `Enqueue`/`RequestDone`
    /// effects, preserving the discrete-event push order bit-for-bit.
    fn resolve(&mut self, now: Micros, base: usize, fx: &mut Vec<Effect>) {
        let mut i = base;
        while i < fx.len() {
            if !matches!(fx[i], Effect::Reroute { .. } | Effect::Advance { .. }) {
                i += 1;
                continue;
            }
            let mut sub = Vec::new();
            match fx.remove(i) {
                Effect::Reroute {
                    from,
                    queued,
                    is_root,
                } => self.front.reroute(now, from, queued, is_root, &mut sub),
                Effect::Advance { sgs, req, f, lost } => {
                    self.shards[sgs.0 as usize].advance(now, req, f, lost, &mut sub);
                }
                _ => unreachable!("matched above"),
            }
            // Re-examine from `i`: the expansion may forward again.
            fx.splice(i..i, sub);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MS};
    use crate::dag::DagSpec;

    fn cfg(num_sgs: usize, workers: usize, cores: u32) -> Config {
        let mut cfg = Config::default();
        cfg.cluster = ClusterConfig {
            num_sgs,
            workers_per_sgs: workers,
            cores_per_worker: cores,
            worker_mem_mb: 16 * 1024,
            proactive_pool_mb: 8 * 1024,
        };
        cfg
    }

    fn chain_core() -> Coordinator {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::chain(
            DagId(0),
            "chain",
            &[(20 * MS, 150 * MS, 128), (30 * MS, 150 * MS, 128)],
            300 * MS,
        ));
        let mut core = Coordinator::new(cfg(1, 2, 4), registry, 0, 7);
        core.register_all_dags();
        core
    }

    /// Drive the core by hand, applying effects immediately: `Enqueue`
    /// recurses, `Dispatched` is collected for the caller to "complete".
    fn settle(core: &mut Coordinator, now: Micros, fx: &mut Vec<Effect>) -> Vec<Effect> {
        let mut out = Vec::new();
        while !fx.is_empty() {
            let batch: Vec<Effect> = std::mem::take(fx);
            for e in batch {
                match e {
                    Effect::Enqueue {
                        sgs,
                        queued,
                        is_root,
                        ..
                    } => core.enqueue(now, sgs, queued, is_root, fx),
                    other => out.push(other),
                }
            }
        }
        out
    }

    #[test]
    fn admit_runs_a_chain_dag_through_both_functions() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let exec: Vec<Micros> = vec![20 * MS, 30 * MS];
        let req = core.admit(0, DagId(0), exec, None, &mut fx);
        assert_eq!(core.inflight(), 1);
        let effects = settle(&mut core, 0, &mut fx);
        // one root dispatched, cold
        let (sgs, epoch, d0) = match &effects[..] {
            [Effect::Dispatched {
                sgs,
                epoch,
                dispatch,
            }] => (*sgs, *epoch, dispatch.clone()),
            other => panic!("expected one dispatch, got {other:?}"),
        };
        assert_eq!(d0.req, req);
        assert!(d0.cold);
        // complete fn 0: fn 1 becomes ready and dispatches
        core.fn_complete(d0.finish_at, sgs, d0.worker, epoch, req, d0.f, &mut fx);
        let effects = settle(&mut core, d0.finish_at, &mut fx);
        let d1 = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { dispatch, .. } => Some(dispatch.clone()),
                _ => None,
            })
            .expect("child dispatched");
        assert_eq!(d1.f.idx, 1);
        // complete fn 1: the request finishes
        core.fn_complete(d1.finish_at, sgs, d1.worker, epoch, req, d1.f, &mut fx);
        let effects = settle(&mut core, d1.finish_at, &mut fx);
        let done = effects.iter().any(|e| matches!(e, Effect::RequestDone { req: r, .. } if *r == req));
        assert!(done, "expected RequestDone, got {effects:?}");
        assert_eq!(core.inflight(), 0);
        assert_eq!(core.merged_metrics().total.completed, 1);
        core.check_invariants().unwrap();
    }

    #[test]
    fn deadline_override_applies_per_request() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let req = core.admit(1000, DagId(0), vec![20 * MS, 30 * MS], Some(70 * MS), &mut fx);
        assert_eq!(core.request(req).unwrap().deadline_abs, 1000 + 70 * MS);
        let req2 = core.admit(1000, DagId(0), vec![20 * MS, 30 * MS], None, &mut fx);
        assert_eq!(core.request(req2).unwrap().deadline_abs, 1000 + 300 * MS);
    }

    #[test]
    fn stale_epoch_completion_reenqueues_instead_of_advancing() {
        let mut core = chain_core();
        let mut fx = Vec::new();
        let req = core.admit(0, DagId(0), vec![20 * MS, 30 * MS], None, &mut fx);
        let effects = settle(&mut core, 0, &mut fx);
        let (sgs, d0) = match &effects[..] {
            [Effect::Dispatched { sgs, dispatch, .. }] => (*sgs, dispatch.clone()),
            other => panic!("{other:?}"),
        };
        // the worker fails while fn 0 runs
        core.fail_worker(sgs, d0.worker);
        core.recover_worker(sgs, d0.worker);
        core.fn_complete(d0.finish_at, sgs, d0.worker, 0, req, d0.f, &mut fx);
        let effects = settle(&mut core, d0.finish_at, &mut fx);
        // the lost execution was re-enqueued and re-dispatched, still fn 0
        let redisp = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { dispatch, .. } => Some(dispatch.clone()),
                _ => None,
            })
            .expect("re-dispatch after lost execution");
        assert_eq!(redisp.f.idx, 0);
        assert_eq!(core.inflight(), 1, "request still in flight");
    }

    #[test]
    fn sgs_failure_reroutes_queued_work() {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::single(DagId(0), "t", 50 * MS, 200 * MS, 128, 200 * MS));
        let mut core = Coordinator::new(cfg(2, 1, 1), registry, 0, 7);
        core.register_all_dags();
        let mut fx = Vec::new();
        // saturate the single core of whichever SGS routing picks, then
        // queue two more requests behind it
        for _ in 0..3 {
            core.admit(0, DagId(0), vec![50 * MS], None, &mut fx);
        }
        let effects = settle(&mut core, 0, &mut fx);
        let sgs = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { sgs, .. } => Some(*sgs),
                _ => None,
            })
            .expect("at least one dispatch");
        let queued_before = core.sgs(sgs).queue.len();
        assert!(queued_before > 0, "some requests must be queued");
        core.sgs_fail(0, sgs, &mut fx);
        // orphaned entries come back as Enqueue effects to the other SGS
        let mut reroutes = 0;
        for e in &*fx {
            if let Effect::Enqueue { sgs: alt, .. } = e {
                assert_ne!(*alt, sgs, "rerouted to the dead SGS");
                reroutes += 1;
            }
        }
        assert_eq!(reroutes, queued_before);
    }

    #[test]
    fn sgs_failure_migrates_request_state_and_straggler_completions_follow() {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::chain(
            DagId(0),
            "chain",
            &[(20 * MS, 150 * MS, 128), (30 * MS, 150 * MS, 128)],
            1_000 * MS,
        ));
        let mut core = Coordinator::new(cfg(2, 1, 1), registry, 0, 7);
        core.register_all_dags();
        let mut fx = Vec::new();
        let req = core.admit(0, DagId(0), vec![20 * MS, 30 * MS], None, &mut fx);
        let effects = settle(&mut core, 0, &mut fx);
        let (home, epoch, d0) = match &effects[..] {
            [Effect::Dispatched {
                sgs,
                epoch,
                dispatch,
            }] => (*sgs, *epoch, dispatch.clone()),
            other => panic!("{other:?}"),
        };
        // the home SGS dies while fn 0 is running on its worker
        core.sgs_fail(10, home, &mut fx);
        assert!(fx.is_empty(), "no queued work to re-route");
        let new_home = core.request(req).expect("migrated, not lost").sgs;
        assert_ne!(new_home, home, "state re-homed to a live SGS");
        // the in-flight completion arrives at the dead shard and must
        // chase the migrated state: fn 1 dispatches at the new home
        core.fn_complete(d0.finish_at, home, d0.worker, epoch, req, d0.f, &mut fx);
        let effects = settle(&mut core, d0.finish_at, &mut fx);
        let (sgs1, d1) = effects
            .iter()
            .find_map(|e| match e {
                Effect::Dispatched { sgs, dispatch, .. } => Some((*sgs, dispatch.clone())),
                _ => None,
            })
            .expect("child dispatched after migration");
        assert_eq!(sgs1, new_home, "downstream runs at the new home SGS");
        assert_eq!(d1.f.idx, 1);
        core.check_invariants().unwrap();
    }

    #[test]
    fn merged_metrics_aggregate_across_shards() {
        let mut registry = DagRegistry::new();
        registry.register(DagSpec::single(DagId(0), "a", 10 * MS, 50 * MS, 128, 100 * MS));
        registry.register(DagSpec::single(DagId(1), "b", 10 * MS, 50 * MS, 128, 100 * MS));
        let mut core = Coordinator::new(cfg(2, 1, 2), registry, 0, 7);
        core.register_all_dags();
        let mut fx = Vec::new();
        for (i, dag) in [DagId(0), DagId(1), DagId(0), DagId(1)].into_iter().enumerate() {
            let t0 = i as u64 * 200 * MS;
            let req = core.admit(t0, dag, vec![10 * MS], None, &mut fx);
            let effects = settle(&mut core, t0, &mut fx);
            let (sgs, epoch, d) = effects
                .iter()
                .find_map(|e| match e {
                    Effect::Dispatched {
                        sgs,
                        epoch,
                        dispatch,
                    } => Some((*sgs, *epoch, dispatch.clone())),
                    _ => None,
                })
                .expect("dispatched");
            core.fn_complete(d.finish_at, sgs, d.worker, epoch, req, d.f, &mut fx);
            settle(&mut core, d.finish_at, &mut fx);
        }
        let merged = core.merged_metrics();
        assert_eq!(merged.total.completed, 4);
        let per_shard: u64 = core.shards.iter().map(|s| s.metrics.total.completed).sum();
        assert_eq!(per_shard, 4, "every completion recorded on exactly one shard");
        assert_eq!(merged.dag(DagId(0)).unwrap().completed, 2);
        assert_eq!(merged.dag(DagId(1)).unwrap().completed, 2);
    }
}
