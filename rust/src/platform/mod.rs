//! Full-system assembly: LBS + SGSs + worker pools (§3's request
//! control flow, Fig 3), shared between two drivers.
//!
//! A request arrives at the LBS, is routed (lottery, §5.2.3) to one SGS
//! after the routing overhead, gets enqueued there, is scheduled SRSF
//! onto a worker core (paying setup time iff no warm sandbox), and its
//! downstream DAG functions are triggered as dependencies complete. In
//! the background, each SGS runs its estimation loop (§4.3.1) and the
//! LBS runs its per-DAG scaling loop (Pseudocode 2).
//!
//! All of that lives in the driver-agnostic [`coordinator`] core,
//! sharded per SGS (DESIGN.md §Sharding). This module's [`SimPlatform`]
//! is the discrete-event driver: it owns the virtual clock, programs
//! against the single-threaded [`Coordinator`] facade (which visits
//! shards in a fixed order), and translates the core's
//! [`coordinator::Effect`]s into calendar events — applied in the
//! pre-shard push order, so simulation results are bit-identical across
//! the sharding refactor. The wall-clock driver ([`realtime`]) turns
//! the same effects into thread-pool work under one lock per shard —
//! both modes exercise the identical scheduling code.

pub mod coordinator;
pub mod realtime;

use std::collections::HashMap;

use crate::config::{Config, Micros};
use crate::dag::{DagRegistry, FnId};
use crate::lbs::Lbs;
use crate::metrics::Metrics;
use crate::sgs::{QueuedFn, RequestId, Sgs, SgsId};
use crate::sim::{run_until, EventQueue};
use crate::util::rng::Rng;
use crate::worker::WorkerId;
use crate::workload::App;

pub use coordinator::{Coordinator, Effect};

/// Simulation events.
#[derive(Debug)]
enum Event {
    /// Next request of app `app_idx` arrives at the LBS.
    Arrival { app_idx: usize },
    /// A routed request (or a ready downstream function) reaches its SGS.
    SgsEnqueue {
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
    },
    /// A dispatched function finishes on a worker.
    FnComplete {
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
    },
    /// A proactive sandbox setup completes.
    SetupDone {
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
    },
    /// Periodic estimation at one SGS (§4.3.1).
    EstimatorTick { sgs: SgsId },
    /// Periodic LBS scaling evaluation (§5.2).
    LbsControlTick,
    /// Fault injection (§6.1).
    WorkerFail { sgs: SgsId, worker: WorkerId },
    WorkerRecover { sgs: SgsId, worker: WorkerId },
    SgsFail { sgs: SgsId },
}

/// Knobs for a simulation run.
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub seed: u64,
    /// Virtual run length.
    pub horizon: Micros,
    /// Completions before this time are excluded from metrics (system
    /// warm-up transient).
    pub warmup: Micros,
    /// Per-request execution-time noise: exec × U[1−f, 1+f].
    pub exec_noise_frac: f64,
    /// Record per-tick time series (sandbox counts, SGS counts) for the
    /// figure harnesses.
    pub record_series: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            seed: 42,
            horizon: 60 * crate::config::SEC,
            warmup: 5 * crate::config::SEC,
            exec_noise_frac: 0.05,
            record_series: false,
        }
    }
}

/// Named time series recorded during a run (figure data).
pub type Series = HashMap<String, Vec<(Micros, f64)>>;

/// The simulated Archipelago deployment: the coordinator core driven by
/// the discrete-event engine.
pub struct SimPlatform {
    core: Coordinator,
    apps: Vec<App>,
    events: EventQueue<Event>,
    rng: Rng,
    opts: SimOptions,
    pub series: Series,
    /// Reused effect buffer (hot path, avoids per-event allocation).
    fx: Vec<Effect>,
    started: bool,
    /// Per-shard metrics merged at the end of [`Self::run`] (read path
    /// for the figure harnesses).
    merged_metrics: Metrics,
}

impl SimPlatform {
    /// Build a platform hosting `apps` under `cfg`.
    pub fn new(cfg: Config, apps: Vec<App>, opts: SimOptions) -> Self {
        let mut registry = DagRegistry::new();
        let mut apps = apps;
        for app in apps.iter_mut() {
            let id = registry.register(app.dag.clone());
            app.dag.id = id; // keep the app copy in sync
        }
        let core = Coordinator::new(cfg, registry, opts.warmup, opts.seed);
        SimPlatform {
            core,
            apps,
            events: EventQueue::new(),
            rng: Rng::new(opts.seed),
            opts,
            series: HashMap::new(),
            fx: Vec::new(),
            started: false,
            merged_metrics: Metrics::new(),
        }
    }

    pub fn now(&self) -> Micros {
        self.events.now()
    }

    /// The shared coordinator core (request table, LBS, SGSs, metrics).
    pub fn core(&self) -> &Coordinator {
        &self.core
    }

    pub fn cfg(&self) -> &Config {
        self.core.cfg()
    }

    pub fn registry(&self) -> &DagRegistry {
        self.core.registry()
    }

    /// Run-wide metrics: the per-shard collectors merged at the end of
    /// [`Self::run`] (empty before the first run).
    pub fn metrics(&self) -> &Metrics {
        &self.merged_metrics
    }

    pub fn lbs(&self) -> &Lbs {
        self.core.lbs()
    }

    pub fn sgs(&self, id: SgsId) -> &Sgs {
        self.core.sgs(id)
    }

    pub fn sgs_count(&self) -> usize {
        self.core.sgs_count()
    }

    pub fn total_cold_starts(&self) -> u64 {
        self.core.total_cold_starts()
    }

    pub fn events_dispatched(&self) -> u64 {
        self.events.dispatched()
    }

    /// Inject a worker fail-stop at virtual time `at`.
    pub fn inject_worker_failure(&mut self, at: Micros, sgs: SgsId, worker: WorkerId) {
        self.events.push_at(at, Event::WorkerFail { sgs, worker });
    }

    pub fn inject_worker_recovery(&mut self, at: Micros, sgs: SgsId, worker: WorkerId) {
        self.events.push_at(at, Event::WorkerRecover { sgs, worker });
    }

    /// Inject an SGS fail-stop (§6.1: state recovers from the external
    /// store; queued requests are re-routed).
    pub fn inject_sgs_failure(&mut self, at: Micros, sgs: SgsId) {
        self.events.push_at(at, Event::SgsFail { sgs });
    }

    fn bootstrap(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        self.core.register_all_dags();
        // Seed every app's first arrival.
        for idx in 0..self.apps.len() {
            let first = {
                let app = &mut self.apps[idx];
                app.arrivals.next_arrival(0, &mut self.rng)
            };
            self.events.push_at(first, Event::Arrival { app_idx: idx });
        }
        // Periodic loops.
        let est = self.core.cfg().sgs.estimate_interval;
        for s in 0..self.core.sgs_count() {
            self.events
                .push_at(est, Event::EstimatorTick { sgs: SgsId(s as u16) });
        }
        self.events
            .push_at(self.core.cfg().lbs.control_interval, Event::LbsControlTick);
    }

    /// Run the simulation to the horizon and return the metrics summary.
    pub fn run(&mut self) -> crate::metrics::SummaryRow {
        self.bootstrap();
        let horizon = self.opts.horizon;
        // The engine hands us events; we can't borrow self both as queue
        // owner and handler, so we temporarily move the queue out.
        let mut queue = std::mem::take(&mut self.events);
        run_until(&mut queue, self, horizon, |q, platform, ev| {
            platform.handle(q, ev);
        });
        self.events = queue;
        self.merged_metrics = self.core.merged_metrics();
        self.merged_metrics.summary_row()
    }

    // ------------------------------------------------------------------
    // Event handlers: each translates to a coordinator call, then maps
    // the emitted effects back onto the calendar.
    // ------------------------------------------------------------------

    fn handle(&mut self, q: &mut EventQueue<Event>, ev: Event) {
        let now = q.now();
        let mut fx = std::mem::take(&mut self.fx);
        // Each arm applies its effects to the calendar *before* pushing
        // its own follow-up event — same-timestamp events dispatch in
        // push order, so this preserves the pre-refactor ordering.
        match ev {
            Event::Arrival { app_idx } => self.on_arrival(q, app_idx, &mut fx),
            Event::SgsEnqueue {
                sgs,
                queued,
                is_root,
            } => {
                self.core.enqueue(now, sgs, queued, is_root, &mut fx);
                Self::apply(q, &mut fx);
            }
            Event::FnComplete {
                sgs,
                worker,
                epoch,
                req,
                f,
            } => {
                self.core.fn_complete(now, sgs, worker, epoch, req, f, &mut fx);
                Self::apply(q, &mut fx);
            }
            Event::SetupDone {
                sgs,
                worker,
                epoch,
                f,
            } => {
                self.core.setup_done(now, sgs, worker, epoch, f, &mut fx);
                Self::apply(q, &mut fx);
            }
            Event::EstimatorTick { sgs } => {
                self.core.estimator_tick(now, sgs, &mut fx);
                Self::apply(q, &mut fx);
                self.record_sgs_series(now, sgs);
                q.push_after(
                    self.core.cfg().sgs.estimate_interval,
                    Event::EstimatorTick { sgs },
                );
            }
            Event::LbsControlTick => {
                self.core.lbs_control(now, &mut fx);
                Self::apply(q, &mut fx);
                self.record_lbs_series(now);
                q.push_after(self.core.cfg().lbs.control_interval, Event::LbsControlTick);
            }
            Event::WorkerFail { sgs, worker } => self.core.fail_worker(sgs, worker),
            Event::WorkerRecover { sgs, worker } => self.core.recover_worker(sgs, worker),
            Event::SgsFail { sgs } => {
                self.core.sgs_fail(now, sgs, &mut fx);
                Self::apply(q, &mut fx);
            }
        }
        debug_assert!(fx.is_empty(), "unapplied coordinator effects");
        self.fx = fx;
    }

    /// Map coordinator effects onto the event calendar, in order.
    fn apply(q: &mut EventQueue<Event>, fx: &mut Vec<Effect>) {
        for e in fx.drain(..) {
            match e {
                Effect::Enqueue {
                    at,
                    sgs,
                    queued,
                    is_root,
                } => q.push_at(
                    at,
                    Event::SgsEnqueue {
                        sgs,
                        queued,
                        is_root,
                    },
                ),
                Effect::Dispatched {
                    sgs,
                    epoch,
                    dispatch: d,
                } => q.push_at(
                    d.finish_at,
                    Event::FnComplete {
                        sgs,
                        worker: d.worker,
                        epoch,
                        req: d.req,
                        f: d.f,
                    },
                ),
                Effect::SetupStarted { sgs, epoch, setup } => q.push_at(
                    setup.done_at,
                    Event::SetupDone {
                        sgs,
                        worker: setup.worker,
                        epoch,
                        f: setup.f,
                    },
                ),
                // Metrics were recorded by the core; virtual time has no
                // caller waiting on a reply.
                Effect::RequestDone { .. } => {}
                // Cross-shard control effects never escape the facade:
                // `Coordinator` resolves them inline (in pre-shard push
                // order) before returning to the driver.
                Effect::Reroute { .. } | Effect::Advance { .. } => {
                    unreachable!("cross-shard effects are resolved by the Coordinator facade")
                }
            }
        }
    }

    fn on_arrival(&mut self, q: &mut EventQueue<Event>, app_idx: usize, fx: &mut Vec<Effect>) {
        let now = q.now();
        let dag_id = self.apps[app_idx].dag.id;
        // Sample this request's execution times (per-request noise).
        let noise = self.opts.exec_noise_frac;
        let exec_times: Vec<Micros> = self
            .core
            .registry()
            .get(dag_id)
            .functions
            .iter()
            .map(|f| {
                if noise > 0.0 {
                    let m = self.rng.range_f64(1.0 - noise, 1.0 + noise);
                    ((f.exec_time as f64) * m) as Micros
                } else {
                    f.exec_time
                }
            })
            .collect();
        self.core.admit(now, dag_id, exec_times, None, fx);
        // Root enqueues go on the calendar before the next arrival
        // (pre-refactor push order).
        Self::apply(q, fx);
        // Next arrival of this app.
        let next = self.apps[app_idx].arrivals.next_arrival(now, &mut self.rng);
        q.push_at(next, Event::Arrival { app_idx });
    }

    /// Per-SGS observability series (Fig 8b/10/11 data), recorded after
    /// the estimator tick.
    fn record_sgs_series(&mut self, now: Micros, sgs: SgsId) {
        if !self.opts.record_series {
            return;
        }
        let s = self.core.sgs(sgs);
        if s.is_alive() {
            for dag_id in s.estimator.tracked() {
                let dag = self.core.registry().get(dag_id);
                let sandboxes = s.dag_sandbox_count(dag);
                self.series
                    .entry(format!("sandboxes.dag{}.sgs{}", dag_id.0, sgs.0))
                    .or_default()
                    .push((now, f64::from(sandboxes)));
                // "ideal" = sandboxes actually needed right now ≈
                // concurrently busy ones (Fig 8b reference line)
                let busy: u32 = (0..dag.len() as u16)
                    .map(|i| {
                        s.pool
                            .workers
                            .iter()
                            .map(|w| w.sandboxes.get(dag.fn_id(i)).map(|x| x.busy).unwrap_or(0))
                            .sum::<u32>()
                    })
                    .sum();
                self.series
                    .entry(format!("busy.dag{}.sgs{}", dag_id.0, sgs.0))
                    .or_default()
                    .push((now, f64::from(busy)));
            }
        }
        let busy: u32 = s
            .pool
            .workers
            .iter()
            .map(|w| w.cores_total() - w.cores_free())
            .sum();
        self.series
            .entry(format!("busy_cores.sgs{}", sgs.0))
            .or_default()
            .push((now, f64::from(busy)));
        self.series
            .entry(format!("queue_len.sgs{}", sgs.0))
            .or_default()
            .push((now, s.queue.len() as f64));
    }

    /// Per-DAG active-SGS series, recorded after the LBS control tick.
    fn record_lbs_series(&mut self, now: Micros) {
        if !self.opts.record_series {
            return;
        }
        for dag in self.core.registry().iter() {
            self.series
                .entry(format!("active_sgs.dag{}", dag.id.0))
                .or_default()
                .push((now, self.core.lbs().active_sgs(dag.id).len() as f64));
        }
    }

    /// Whole-platform structural invariants (driven by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.core.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MS, SEC};
    use crate::dag::{DagId, DagSpec};
    use crate::workload::{App, ArrivalProcess, DagClass};

    fn small_cfg(num_sgs: usize, workers: usize, cores: u32) -> Config {
        let mut cfg = Config::default();
        cfg.cluster = ClusterConfig {
            num_sgs,
            workers_per_sgs: workers,
            cores_per_worker: cores,
            worker_mem_mb: 16 * 1024,
            proactive_pool_mb: 8 * 1024,
        };
        cfg
    }

    fn one_app(rate: f64) -> Vec<App> {
        let dag = DagSpec::single(DagId(0), "t", 50 * MS, 200 * MS, 128, 200 * MS);
        vec![App {
            class: DagClass::C1,
            dag,
            arrivals: ArrivalProcess::constant(rate),
        }]
    }

    fn opts(horizon_s: u64) -> SimOptions {
        SimOptions {
            seed: 7,
            horizon: horizon_s * SEC,
            warmup: SEC,
            exec_noise_frac: 0.0,
            record_series: false,
        }
    }

    #[test]
    fn single_dag_completes_requests_and_meets_deadlines() {
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), opts(20));
        let row = p.run();
        assert!(row.completed > 1500, "completed {}", row.completed);
        // steady state: proactive sandboxes make most requests warm
        assert!(
            row.deadline_met_rate > 0.98,
            "met {}",
            row.deadline_met_rate
        );
        // p50 ≈ exec + overheads ≪ deadline
        assert!(row.p50 < 60 * MS, "p50 {}", row.p50);
        p.check_invariants().unwrap();
    }

    #[test]
    fn proactive_allocation_reduces_cold_starts_vs_request_count() {
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), opts(20));
        let row = p.run();
        let cold_rate = p.total_cold_starts() as f64 / row.completed as f64;
        assert!(cold_rate < 0.1, "cold rate {cold_rate}");
    }

    #[test]
    fn chain_dag_executes_in_order_and_completes() {
        let dag = DagSpec::chain(
            DagId(0),
            "chain",
            &[(20 * MS, 150 * MS, 128), (30 * MS, 150 * MS, 128)],
            300 * MS,
        );
        let apps = vec![App {
            class: DagClass::C3,
            dag,
            arrivals: ArrivalProcess::constant(50.0),
        }];
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), apps, opts(15));
        let row = p.run();
        assert!(row.completed > 400);
        assert!(row.deadline_met_rate > 0.95, "met {}", row.deadline_met_rate);
        // E2E ≥ sum of execs
        assert!(row.p50 >= 50 * MS, "p50 {}", row.p50);
    }

    #[test]
    fn branched_dag_joins_correctly() {
        use crate::dag::FunctionSpec;
        let functions = vec![
            FunctionSpec::new("root", 10 * MS, 150 * MS, 128),
            FunctionSpec::new("a", 20 * MS, 150 * MS, 128),
            FunctionSpec::new("b", 40 * MS, 150 * MS, 128),
            FunctionSpec::new("join", 10 * MS, 150 * MS, 128),
        ];
        let dag = DagSpec::new(
            DagId(0),
            "diamond",
            functions,
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            400 * MS,
        )
        .unwrap();
        let apps = vec![App {
            class: DagClass::C4,
            dag,
            arrivals: ArrivalProcess::constant(20.0),
        }];
        let mut p = SimPlatform::new(small_cfg(1, 2, 8), apps, opts(15));
        let row = p.run();
        assert!(row.completed > 150);
        // E2E ≥ critical path (10+40+10=60ms)
        assert!(row.p50 >= 60 * MS, "p50 {}", row.p50);
        assert!(row.deadline_met_rate > 0.9);
    }

    #[test]
    fn overload_misses_deadlines() {
        // 2 cores total, 100 rps × 50ms = 5 cores needed → overload
        let mut p = SimPlatform::new(small_cfg(1, 1, 2), one_app(100.0), opts(10));
        let row = p.run();
        assert!(
            row.deadline_met_rate < 0.9,
            "overload must miss deadlines: {}",
            row.deadline_met_rate
        );
    }

    #[test]
    fn scale_out_happens_under_pressure() {
        // One SGS pool is too small; queuing delay must trigger scale-out.
        let mut p = SimPlatform::new(small_cfg(4, 1, 2), one_app(150.0), opts(30));
        p.run();
        let dag = DagId(0);
        assert!(
            p.lbs().active_sgs(dag).len() > 1 || p.lbs().scale_outs() > 0,
            "expected scale-out; active={:?}",
            p.lbs().active_sgs(dag)
        );
    }

    #[test]
    fn no_scale_out_when_single_sgs_suffices() {
        let mut p = SimPlatform::new(small_cfg(4, 2, 8), one_app(50.0), opts(20));
        p.run();
        assert_eq!(p.lbs().active_sgs(DagId(0)).len(), 1);
        assert_eq!(p.lbs().scale_outs(), 0);
    }

    #[test]
    fn worker_failure_recovers() {
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(80.0), opts(20));
        p.inject_worker_failure(5 * SEC, SgsId(0), WorkerId(0));
        p.inject_worker_recovery(10 * SEC, SgsId(0), WorkerId(0));
        let row = p.run();
        assert!(row.completed > 1000, "completed {}", row.completed);
        // most requests still meet deadlines (capacity halved briefly)
        assert!(row.deadline_met_rate > 0.7, "met {}", row.deadline_met_rate);
        p.check_invariants().unwrap();
    }

    #[test]
    fn sgs_failure_reroutes() {
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(80.0), opts(20));
        p.inject_sgs_failure(5 * SEC, SgsId(0));
        let row = p.run();
        assert!(row.completed > 1000, "completed {}", row.completed);
        // the surviving SGS carries the load
        let active = p.lbs().active_sgs(DagId(0));
        assert!(!active.contains(&SgsId(0)), "dead SGS still active");
        p.check_invariants().unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut o = opts(10);
            o.seed = seed;
            let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), o);
            let row = p.run();
            (row.completed, row.p50, row.p99, row.cold_starts)
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn merged_metrics_match_run_summary() {
        // Per-shard metrics merged on read must reproduce the run's own
        // summary row field-for-field.
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), opts(10));
        let row = p.run();
        assert_eq!(p.metrics().summary_row(), row);
        let per_shard: u64 = p
            .core()
            .shards
            .iter()
            .map(|s| s.metrics.total.completed)
            .sum();
        assert_eq!(per_shard, row.completed, "each completion lands on one shard");
    }

    #[test]
    fn series_recording() {
        let mut o = opts(10);
        o.record_series = true;
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), o);
        p.run();
        assert!(p.series.keys().any(|k| k.starts_with("active_sgs.dag0")));
        assert!(p.series.keys().any(|k| k.starts_with("sandboxes.dag0")));
    }

    #[test]
    fn warmup_excludes_early_completions() {
        let mut o = opts(10);
        o.warmup = 9 * SEC;
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), o);
        let row = p.run();
        let mut o2 = opts(10);
        o2.warmup = 0;
        let mut p2 = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), o2);
        let row2 = p2.run();
        assert!(row.completed < row2.completed);
    }
}
