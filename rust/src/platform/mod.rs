//! Full-system assembly: LBS + SGSs + worker pools driven by the
//! discrete-event engine (§3's request control flow, Fig 3).
//!
//! A request arrives at the LBS, is routed (lottery, §5.2.3) to one SGS
//! after the routing overhead, gets enqueued there, is scheduled SRSF
//! onto a worker core (paying setup time iff no warm sandbox), and its
//! downstream DAG functions are triggered as dependencies complete. In
//! the background, each SGS runs its estimation loop (§4.3.1) and the
//! LBS runs its per-DAG scaling loop (Pseudocode 2). The identical
//! policy structs also drive the real-time path (`realtime`).

pub mod realtime;

use std::collections::HashMap;

use crate::util::fasthash::FastMap;

use crate::config::{Config, Micros};
use crate::dag::{DagId, DagRegistry, FnId};
use crate::lbs::{Lbs, ScaleAction, SgsReport};
use crate::metrics::{Metrics, RequestOutcome};
use crate::sgs::{QueuedFn, RequestId, SetupStart, Sgs, SgsId};
use crate::sim::{run_until, EventQueue};
use crate::util::rng::Rng;
use crate::worker::WorkerId;
use crate::workload::App;

/// Simulation events.
#[derive(Debug)]
enum Event {
    /// Next request of app `app_idx` arrives at the LBS.
    Arrival { app_idx: usize },
    /// A routed request (or a ready downstream function) reaches its SGS.
    SgsEnqueue {
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
    },
    /// A dispatched function finishes on a worker.
    FnComplete {
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        cold: bool,
    },
    /// A proactive sandbox setup completes.
    SetupDone {
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
    },
    /// Periodic estimation at one SGS (§4.3.1).
    EstimatorTick { sgs: SgsId },
    /// Periodic LBS scaling evaluation (§5.2).
    LbsControlTick,
    /// Fault injection (§6.1).
    WorkerFail { sgs: SgsId, worker: WorkerId },
    WorkerRecover { sgs: SgsId, worker: WorkerId },
    SgsFail { sgs: SgsId },
}

/// Per-request in-flight bookkeeping.
#[derive(Debug)]
struct RequestState {
    dag: DagId,
    arrival: Micros,
    deadline_abs: Micros,
    sgs: SgsId,
    /// Outstanding parent count per function.
    pending_parents: Vec<u16>,
    /// Functions not yet completed.
    remaining: usize,
    cold_starts: u32,
    /// Sampled execution time per function for this request.
    exec_times: Vec<Micros>,
}

/// Knobs for a simulation run.
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub seed: u64,
    /// Virtual run length.
    pub horizon: Micros,
    /// Completions before this time are excluded from metrics (system
    /// warm-up transient).
    pub warmup: Micros,
    /// Per-request execution-time noise: exec × U[1−f, 1+f].
    pub exec_noise_frac: f64,
    /// Record per-tick time series (sandbox counts, SGS counts) for the
    /// figure harnesses.
    pub record_series: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            seed: 42,
            horizon: 60 * crate::config::SEC,
            warmup: 5 * crate::config::SEC,
            exec_noise_frac: 0.05,
            record_series: false,
        }
    }
}

/// Named time series recorded during a run (figure data).
pub type Series = HashMap<String, Vec<(Micros, f64)>>;

/// The simulated Archipelago deployment.
pub struct SimPlatform {
    pub cfg: Config,
    pub registry: DagRegistry,
    apps: Vec<App>,
    lbs: Lbs,
    sgss: Vec<Sgs>,
    events: EventQueue<Event>,
    pub metrics: Metrics,
    requests: FastMap<u64, RequestState>,
    next_req: u64,
    rng: Rng,
    opts: SimOptions,
    pub series: Series,
    /// Reused dispatch buffer (hot path, avoids per-event allocation).
    dispatch_buf: Vec<crate::sgs::Dispatch>,
    started: bool,
}

impl SimPlatform {
    /// Build a platform hosting `apps` under `cfg`.
    pub fn new(cfg: Config, apps: Vec<App>, opts: SimOptions) -> Self {
        cfg.validate().expect("invalid config");
        let mut registry = DagRegistry::new();
        let mut apps = apps;
        for app in apps.iter_mut() {
            let id = registry.register(app.dag.clone());
            app.dag.id = id; // keep the app copy in sync
        }
        let sgss: Vec<Sgs> = (0..cfg.cluster.num_sgs)
            .map(|i| {
                Sgs::new(
                    SgsId(i as u16),
                    cfg.cluster.workers_per_sgs,
                    cfg.cluster.cores_per_worker,
                    cfg.cluster.proactive_pool_mb,
                    cfg.sgs.clone(),
                )
            })
            .collect();
        let lbs = Lbs::new(cfg.lbs.clone(), cfg.cluster.num_sgs, opts.seed);
        SimPlatform {
            registry,
            apps,
            lbs,
            sgss,
            events: EventQueue::new(),
            metrics: Metrics::new(),
            requests: FastMap::default(),
            next_req: 0,
            rng: Rng::new(opts.seed),
            opts,
            cfg,
            series: HashMap::new(),
            dispatch_buf: Vec::new(),
            started: false,
        }
    }

    pub fn now(&self) -> Micros {
        self.events.now()
    }

    pub fn lbs(&self) -> &Lbs {
        &self.lbs
    }

    pub fn sgs(&self, id: SgsId) -> &Sgs {
        &self.sgss[id.0 as usize]
    }

    pub fn sgs_count(&self) -> usize {
        self.sgss.len()
    }

    pub fn total_cold_starts(&self) -> u64 {
        self.sgss.iter().map(|s| s.cold_starts()).sum()
    }

    pub fn events_dispatched(&self) -> u64 {
        self.events.dispatched()
    }

    /// Inject a worker fail-stop at virtual time `at`.
    pub fn inject_worker_failure(&mut self, at: Micros, sgs: SgsId, worker: WorkerId) {
        self.events.push_at(at, Event::WorkerFail { sgs, worker });
    }

    pub fn inject_worker_recovery(&mut self, at: Micros, sgs: SgsId, worker: WorkerId) {
        self.events.push_at(at, Event::WorkerRecover { sgs, worker });
    }

    /// Inject an SGS fail-stop (§6.1: state recovers from the external
    /// store; queued requests are re-routed).
    pub fn inject_sgs_failure(&mut self, at: Micros, sgs: SgsId) {
        self.events.push_at(at, Event::SgsFail { sgs });
    }

    fn bootstrap(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        // Register every app and seed its first arrival.
        for idx in 0..self.apps.len() {
            let dag_id = self.apps[idx].dag.id;
            self.lbs.register_dag(dag_id);
            let first = {
                let app = &mut self.apps[idx];
                app.arrivals.next_arrival(0, &mut self.rng)
            };
            self.events.push_at(first, Event::Arrival { app_idx: idx });
        }
        // Periodic loops.
        let est = self.cfg.sgs.estimate_interval;
        for s in 0..self.sgss.len() {
            self.events
                .push_at(est, Event::EstimatorTick { sgs: SgsId(s as u16) });
        }
        self.events
            .push_at(self.cfg.lbs.control_interval, Event::LbsControlTick);
    }

    /// Run the simulation to the horizon and return the metrics summary.
    pub fn run(&mut self) -> crate::metrics::SummaryRow {
        self.bootstrap();
        let horizon = self.opts.horizon;
        // The engine hands us events; we can't borrow self both as queue
        // owner and handler, so we temporarily move the queue out.
        let mut queue = std::mem::take(&mut self.events);
        run_until(&mut queue, self, horizon, |q, platform, ev| {
            platform.handle(q, ev);
        });
        self.events = queue;
        self.metrics.summary_row()
    }

    // ------------------------------------------------------------------
    // Event handlers
    // ------------------------------------------------------------------

    fn handle(&mut self, q: &mut EventQueue<Event>, ev: Event) {
        match ev {
            Event::Arrival { app_idx } => self.on_arrival(q, app_idx),
            Event::SgsEnqueue {
                sgs,
                queued,
                is_root,
            } => {
                self.on_enqueue(q, sgs, queued, is_root);
            }
            Event::FnComplete {
                sgs,
                worker,
                epoch,
                req,
                f,
                cold,
            } => self.on_fn_complete(q, sgs, worker, epoch, req, f, cold),
            Event::SetupDone {
                sgs,
                worker,
                epoch,
                f,
            } => self.on_setup_done(q, sgs, worker, epoch, f),
            Event::EstimatorTick { sgs } => self.on_estimator_tick(q, sgs),
            Event::LbsControlTick => self.on_lbs_control(q),
            Event::WorkerFail { sgs, worker } => {
                self.sgss[sgs.0 as usize].fail_worker(worker);
            }
            Event::WorkerRecover { sgs, worker } => {
                self.sgss[sgs.0 as usize].recover_worker(worker);
            }
            Event::SgsFail { sgs } => self.on_sgs_fail(q, sgs),
        }
    }

    fn on_arrival(&mut self, q: &mut EventQueue<Event>, app_idx: usize) {
        let now = q.now();
        let dag_id = self.apps[app_idx].dag.id;
        let dag = self.registry.get(dag_id);
        // Build the request.
        let req_id = RequestId(self.next_req);
        self.next_req += 1;
        let noise = self.opts.exec_noise_frac;
        let exec_times: Vec<Micros> = dag
            .functions
            .iter()
            .map(|f| {
                if noise > 0.0 {
                    let m = self.rng.range_f64(1.0 - noise, 1.0 + noise);
                    ((f.exec_time as f64) * m) as Micros
                } else {
                    f.exec_time
                }
            })
            .collect();
        let state = RequestState {
            dag: dag_id,
            arrival: now,
            deadline_abs: now + dag.deadline,
            sgs: SgsId(0), // set below
            pending_parents: dag.parent_count.clone(),
            remaining: dag.len(),
            cold_starts: 0,
            exec_times,
        };
        // Route (the paper's per-request LBS decision).
        let sgs = self.lbs.route(dag_id);
        let mut state = state;
        state.sgs = sgs;
        // Enqueue the roots after the routing overhead.
        let enqueue_at = now + self.cfg.lbs.route_overhead;
        for &root in &self.registry.get(dag_id).roots {
            let queued = self.make_queued(&state, req_id, dag_id, root, enqueue_at);
            q.push_at(
                enqueue_at,
                Event::SgsEnqueue {
                    sgs,
                    queued,
                    is_root: true,
                },
            );
        }
        self.requests.insert(req_id.0, state);
        // Next arrival of this app.
        let next = self.apps[app_idx]
            .arrivals
            .next_arrival(now, &mut self.rng);
        q.push_at(next, Event::Arrival { app_idx });
    }

    fn make_queued(
        &self,
        state: &RequestState,
        req: RequestId,
        dag_id: DagId,
        fn_idx: u16,
        enqueued_at: Micros,
    ) -> QueuedFn {
        let dag = self.registry.get(dag_id);
        let spec = &dag.functions[fn_idx as usize];
        QueuedFn {
            req,
            f: dag.fn_id(fn_idx),
            dag: dag_id,
            enqueued_at,
            deadline_abs: state.deadline_abs,
            remaining_work: dag.cpl[fn_idx as usize],
            exec_time: state.exec_times[fn_idx as usize],
            setup_time: spec.setup_time,
            mem_mb: spec.mem_mb,
        }
    }

    fn on_enqueue(
        &mut self,
        q: &mut EventQueue<Event>,
        sgs: SgsId,
        queued: QueuedFn,
        is_root: bool,
    ) {
        let s = &mut self.sgss[sgs.0 as usize];
        if !s.is_alive() {
            // Failure between routing and enqueue: reroute through LBS.
            let dag = queued.dag;
            let alt = self.lbs.route(dag);
            if alt != sgs {
                q.push_after(
                    self.cfg.lbs.route_overhead,
                    Event::SgsEnqueue {
                        sgs: alt,
                        queued,
                        is_root,
                    },
                );
            }
            return;
        }
        s.enqueue(queued, is_root);
        self.dispatch(q, sgs);
    }

    /// Run the SGS dispatch loop and schedule completion events.
    fn dispatch(&mut self, q: &mut EventQueue<Event>, sgs: SgsId) {
        let now = q.now();
        let s = &mut self.sgss[sgs.0 as usize];
        let mut dispatches = std::mem::take(&mut self.dispatch_buf);
        s.try_dispatch_into(now, &mut dispatches);
        for d in dispatches.drain(..) {
            let epoch = s.pool.get(d.worker).epoch();
            if now >= self.opts.warmup {
                self.metrics.record_qdelay(d.f.dag, d.queue_delay);
            }
            if let Some(state) = self.requests.get_mut(&d.req.0) {
                state.cold_starts += u32::from(d.cold);
            }
            q.push_at(
                d.finish_at,
                Event::FnComplete {
                    sgs,
                    worker: d.worker,
                    epoch,
                    req: d.req,
                    f: d.f,
                    cold: d.cold,
                },
            );
        }
        self.dispatch_buf = dispatches;
    }

    #[allow(clippy::too_many_arguments)]
    fn on_fn_complete(
        &mut self,
        q: &mut EventQueue<Event>,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        req: RequestId,
        f: FnId,
        _cold: bool,
    ) {
        let now = q.now();
        let s = &mut self.sgss[sgs.0 as usize];
        let current_epoch = s.pool.get(worker).epoch();
        if current_epoch != epoch || !s.pool.get(worker).is_alive() {
            // The worker died while this function ran: the execution is
            // lost; re-enqueue the function (at-least-once semantics).
            if self.requests.contains_key(&req.0) {
                let state = &self.requests[&req.0];
                let queued = self.make_queued(state, req, state.dag, f.idx, now);
                let target = state.sgs;
                q.push_at(
                    now,
                    Event::SgsEnqueue {
                        sgs: target,
                        queued,
                        is_root: false,
                    },
                );
            }
            return;
        }
        s.complete(worker, f, now);

        // Advance the request's DAG.
        let mut finished = false;
        let mut children_ready: Vec<u16> = Vec::new();
        if let Some(state) = self.requests.get_mut(&req.0) {
            state.remaining -= 1;
            finished = state.remaining == 0;
            let dag = self.registry.get(state.dag);
            for &c in &dag.children[f.idx as usize] {
                state.pending_parents[c as usize] -= 1;
                if state.pending_parents[c as usize] == 0 {
                    children_ready.push(c);
                }
            }
        }
        if finished {
            let state = self.requests.remove(&req.0).expect("finished implies present");
            if now >= self.opts.warmup {
                self.metrics.record_completion(&RequestOutcome {
                    dag: state.dag,
                    arrival: state.arrival,
                    completion: now,
                    deadline_abs: state.deadline_abs,
                    cold_starts: state.cold_starts,
                });
            }
        } else if !children_ready.is_empty() {
            let state = &self.requests[&req.0];
            // Downstream functions run at the same SGS — §4.2: "As an SGS
            // is DAG aware, it schedules functions once their
            // dependencies are met."
            let target = state.sgs;
            for c in children_ready {
                let queued = self.make_queued(state, req, state.dag, c, now);
                q.push_at(
                    now,
                    Event::SgsEnqueue {
                        sgs: target,
                        queued,
                        is_root: false,
                    },
                );
            }
        }
        // The freed core may admit more queued work.
        self.dispatch(q, sgs);
    }

    fn on_setup_done(
        &mut self,
        q: &mut EventQueue<Event>,
        sgs: SgsId,
        worker: WorkerId,
        epoch: u64,
        f: FnId,
    ) {
        let s = &mut self.sgss[sgs.0 as usize];
        if s.pool.get(worker).epoch() != epoch {
            return; // worker failed mid-setup; sandbox lost
        }
        s.setup_done(worker, f);
        // A fresh warm sandbox can convert a would-be-cold dispatch.
        self.dispatch(q, sgs);
    }

    fn on_estimator_tick(&mut self, q: &mut EventQueue<Event>, sgs: SgsId) {
        let now = q.now();
        let alive = self.sgss[sgs.0 as usize].is_alive();
        if alive {
            let setups = {
                let s = &mut self.sgss[sgs.0 as usize];
                s.estimator_tick(now, &self.registry)
            };
            self.schedule_setups(q, sgs, &setups);
            // Piggyback per-DAG reports to the LBS (§5.2.1).
            let tracked = self.sgss[sgs.0 as usize].estimator.tracked();
            for dag_id in tracked {
                let s = &self.sgss[sgs.0 as usize];
                let dag = self.registry.get(dag_id);
                let report = SgsReport {
                    sgs,
                    sandboxes: s.dag_sandbox_count(dag),
                    qdelay_us: s.estimator.qdelay(dag_id).unwrap_or(0.0),
                    window_full: s.estimator.qdelay_window_full(dag_id),
                };
                self.lbs.update_report(dag_id, report);
                if self.opts.record_series {
                    self.series
                        .entry(format!("sandboxes.dag{}.sgs{}", dag_id.0, sgs.0))
                        .or_default()
                        .push((now, f64::from(report.sandboxes)));
                    // "ideal" = sandboxes actually needed right now ≈
                    // concurrently busy ones (Fig 8b reference line)
                    let busy: u32 = (0..dag.len() as u16)
                        .map(|i| {
                            s.pool
                                .workers
                                .iter()
                                .map(|w| {
                                    w.sandboxes.get(dag.fn_id(i)).map(|x| x.busy).unwrap_or(0)
                                })
                                .sum::<u32>()
                        })
                        .sum();
                    self.series
                        .entry(format!("busy.dag{}.sgs{}", dag_id.0, sgs.0))
                        .or_default()
                        .push((now, f64::from(busy)));
                }
            }
        }
        if self.opts.record_series {
            let s = &self.sgss[sgs.0 as usize];
            let busy: u32 = s
                .pool
                .workers
                .iter()
                .map(|w| w.cores_total() - w.cores_free())
                .sum();
            self.series
                .entry(format!("busy_cores.sgs{}", sgs.0))
                .or_default()
                .push((now, f64::from(busy)));
            self.series
                .entry(format!("queue_len.sgs{}", sgs.0))
                .or_default()
                .push((now, self.sgss[sgs.0 as usize].queue.len() as f64));
        }
        q.push_after(
            self.cfg.sgs.estimate_interval,
            Event::EstimatorTick { sgs },
        );
    }

    fn schedule_setups(&mut self, q: &mut EventQueue<Event>, sgs: SgsId, setups: &[SetupStart]) {
        for su in setups {
            let epoch = self.sgss[sgs.0 as usize].pool.get(su.worker).epoch();
            q.push_at(
                su.done_at,
                Event::SetupDone {
                    sgs,
                    worker: su.worker,
                    epoch,
                    f: su.f,
                },
            );
        }
    }

    fn on_lbs_control(&mut self, q: &mut EventQueue<Event>) {
        let now = q.now();
        let dag_ids: Vec<DagId> = self.registry.iter().map(|d| d.id).collect();
        for dag_id in dag_ids {
            let slack = self.registry.get(dag_id).slack();
            let actions = self.lbs.control_tick(dag_id, slack);
            for action in actions {
                match action {
                    ScaleAction::Out {
                        dag,
                        sgs,
                        prime_target,
                        expected_rate,
                    } => {
                        let setups = self.sgss[sgs.0 as usize].prime_dag(
                            now,
                            dag,
                            prime_target,
                            expected_rate,
                            &self.registry,
                        );
                        self.schedule_setups(q, sgs, &setups);
                    }
                    ScaleAction::In { .. } => {
                        // Gradual drain: the SGS keeps serving discounted
                        // lottery traffic; its estimator decays demand.
                    }
                    ScaleAction::Drop { dag, sgs } => {
                        self.sgss[sgs.0 as usize].release_dag(dag, &self.registry);
                    }
                    ScaleAction::ResetWindows { dag } => {
                        let mut members: Vec<SgsId> = self.lbs.active_sgs(dag).to_vec();
                        members.extend(self.lbs.removed_sgs(dag));
                        for sgs in members {
                            self.sgss[sgs.0 as usize]
                                .estimator
                                .reset_qdelay_window(dag);
                        }
                    }
                }
            }
            if self.opts.record_series {
                self.series
                    .entry(format!("active_sgs.dag{}", dag_id.0))
                    .or_default()
                    .push((now, self.lbs.active_sgs(dag_id).len() as f64));
            }
        }
        q.push_after(self.cfg.lbs.control_interval, Event::LbsControlTick);
    }

    fn on_sgs_fail(&mut self, q: &mut EventQueue<Event>, sgs: SgsId) {
        // Fail-stop the scheduler process. Worker machines are separate;
        // running functions complete, but the scheduling queue is lost
        // and recovered by re-routing through the LBS (§6.1: SGS state
        // lives in the external store; queued work is re-dispatched).
        let orphaned = self.sgss[sgs.0 as usize].fail();
        self.lbs.remove_sgs(sgs);
        for queued in orphaned {
            let dag = queued.dag;
            let alt = self.lbs.route(dag);
            // Requests whose home SGS died move entirely.
            if let Some(state) = self
                .requests
                .values_mut()
                .find(|r| r.sgs == sgs && r.dag == dag)
            {
                state.sgs = alt;
            }
            q.push_after(
                self.cfg.lbs.route_overhead,
                Event::SgsEnqueue {
                    sgs: alt,
                    queued,
                    is_root: false,
                },
            );
        }
        // Reassign home SGS for all in-flight requests of the dead SGS.
        let reassign: Vec<u64> = self
            .requests
            .iter()
            .filter(|(_, r)| r.sgs == sgs)
            .map(|(id, _)| *id)
            .collect();
        for id in reassign {
            let dag = self.requests[&id].dag;
            let alt = self.lbs.route(dag);
            self.requests.get_mut(&id).unwrap().sgs = alt;
        }
    }

    /// Whole-platform structural invariants (driven by property tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        for s in &self.sgss {
            s.check_invariants()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, MS, SEC};
    use crate::dag::DagSpec;
    use crate::workload::{App, ArrivalProcess, DagClass};

    fn small_cfg(num_sgs: usize, workers: usize, cores: u32) -> Config {
        let mut cfg = Config::default();
        cfg.cluster = ClusterConfig {
            num_sgs,
            workers_per_sgs: workers,
            cores_per_worker: cores,
            worker_mem_mb: 16 * 1024,
            proactive_pool_mb: 8 * 1024,
        };
        cfg
    }

    fn one_app(rate: f64) -> Vec<App> {
        let dag = DagSpec::single(DagId(0), "t", 50 * MS, 200 * MS, 128, 200 * MS);
        vec![App {
            class: DagClass::C1,
            dag,
            arrivals: ArrivalProcess::constant(rate),
        }]
    }

    fn opts(horizon_s: u64) -> SimOptions {
        SimOptions {
            seed: 7,
            horizon: horizon_s * SEC,
            warmup: SEC,
            exec_noise_frac: 0.0,
            record_series: false,
        }
    }

    #[test]
    fn single_dag_completes_requests_and_meets_deadlines() {
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), opts(20));
        let row = p.run();
        assert!(row.completed > 1500, "completed {}", row.completed);
        // steady state: proactive sandboxes make most requests warm
        assert!(
            row.deadline_met_rate > 0.98,
            "met {}",
            row.deadline_met_rate
        );
        // p50 ≈ exec + overheads ≪ deadline
        assert!(row.p50 < 60 * MS, "p50 {}", row.p50);
        p.check_invariants().unwrap();
    }

    #[test]
    fn proactive_allocation_reduces_cold_starts_vs_request_count() {
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), opts(20));
        let row = p.run();
        let cold_rate = p.total_cold_starts() as f64 / row.completed as f64;
        assert!(cold_rate < 0.1, "cold rate {cold_rate}");
    }

    #[test]
    fn chain_dag_executes_in_order_and_completes() {
        let dag = DagSpec::chain(
            DagId(0),
            "chain",
            &[(20 * MS, 150 * MS, 128), (30 * MS, 150 * MS, 128)],
            300 * MS,
        );
        let apps = vec![App {
            class: DagClass::C3,
            dag,
            arrivals: ArrivalProcess::constant(50.0),
        }];
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), apps, opts(15));
        let row = p.run();
        assert!(row.completed > 400);
        assert!(row.deadline_met_rate > 0.95, "met {}", row.deadline_met_rate);
        // E2E ≥ sum of execs
        assert!(row.p50 >= 50 * MS, "p50 {}", row.p50);
    }

    #[test]
    fn branched_dag_joins_correctly() {
        use crate::dag::FunctionSpec;
        let functions = vec![
            FunctionSpec::new("root", 10 * MS, 150 * MS, 128),
            FunctionSpec::new("a", 20 * MS, 150 * MS, 128),
            FunctionSpec::new("b", 40 * MS, 150 * MS, 128),
            FunctionSpec::new("join", 10 * MS, 150 * MS, 128),
        ];
        let dag = DagSpec::new(
            DagId(0),
            "diamond",
            functions,
            vec![(0, 1), (0, 2), (1, 3), (2, 3)],
            400 * MS,
        )
        .unwrap();
        let apps = vec![App {
            class: DagClass::C4,
            dag,
            arrivals: ArrivalProcess::constant(20.0),
        }];
        let mut p = SimPlatform::new(small_cfg(1, 2, 8), apps, opts(15));
        let row = p.run();
        assert!(row.completed > 150);
        // E2E ≥ critical path (10+40+10=60ms)
        assert!(row.p50 >= 60 * MS, "p50 {}", row.p50);
        assert!(row.deadline_met_rate > 0.9);
    }

    #[test]
    fn overload_misses_deadlines() {
        // 2 cores total, 100 rps × 50ms = 5 cores needed → overload
        let mut p = SimPlatform::new(small_cfg(1, 1, 2), one_app(100.0), opts(10));
        let row = p.run();
        assert!(
            row.deadline_met_rate < 0.9,
            "overload must miss deadlines: {}",
            row.deadline_met_rate
        );
    }

    #[test]
    fn scale_out_happens_under_pressure() {
        // One SGS pool is too small; queuing delay must trigger scale-out.
        let mut p = SimPlatform::new(small_cfg(4, 1, 2), one_app(150.0), opts(30));
        p.run();
        let dag = DagId(0);
        assert!(
            p.lbs().active_sgs(dag).len() > 1 || p.lbs().scale_outs() > 0,
            "expected scale-out; active={:?}",
            p.lbs().active_sgs(dag)
        );
    }

    #[test]
    fn no_scale_out_when_single_sgs_suffices() {
        let mut p = SimPlatform::new(small_cfg(4, 2, 8), one_app(50.0), opts(20));
        p.run();
        assert_eq!(p.lbs().active_sgs(DagId(0)).len(), 1);
        assert_eq!(p.lbs().scale_outs(), 0);
    }

    #[test]
    fn worker_failure_recovers() {
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(80.0), opts(20));
        p.inject_worker_failure(5 * SEC, SgsId(0), WorkerId(0));
        p.inject_worker_recovery(10 * SEC, SgsId(0), WorkerId(0));
        let row = p.run();
        assert!(row.completed > 1000, "completed {}", row.completed);
        // most requests still meet deadlines (capacity halved briefly)
        assert!(row.deadline_met_rate > 0.7, "met {}", row.deadline_met_rate);
        p.check_invariants().unwrap();
    }

    #[test]
    fn sgs_failure_reroutes() {
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(80.0), opts(20));
        p.inject_sgs_failure(5 * SEC, SgsId(0));
        let row = p.run();
        assert!(row.completed > 1000, "completed {}", row.completed);
        // the surviving SGS carries the load
        let active = p.lbs().active_sgs(DagId(0));
        assert!(!active.contains(&SgsId(0)), "dead SGS still active");
        p.check_invariants().unwrap();
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut o = opts(10);
            o.seed = seed;
            let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), o);
            let row = p.run();
            (row.completed, row.p50, row.p99, row.cold_starts)
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn series_recording() {
        let mut o = opts(10);
        o.record_series = true;
        let mut p = SimPlatform::new(small_cfg(2, 2, 4), one_app(100.0), o);
        p.run();
        assert!(p
            .series
            .keys()
            .any(|k| k.starts_with("active_sgs.dag0")));
        assert!(p.series.keys().any(|k| k.starts_with("sandboxes.dag0")));
    }

    #[test]
    fn warmup_excludes_early_completions() {
        let mut o = opts(10);
        o.warmup = 9 * SEC;
        let mut p = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), o);
        let row = p.run();
        let mut o2 = opts(10);
        o2.warmup = 0;
        let mut p2 = SimPlatform::new(small_cfg(1, 2, 4), one_app(100.0), o2);
        let row2 = p2.run();
        assert!(row.completed < row2.completed);
    }
}
