//! Baseline serving stacks the paper compares against.
//!
//! * **Centralized FIFO** (§7.1 "Baseline Stack", like OpenWhisk): one
//!   scheduler with a global FIFO queue over the whole (un-partitioned)
//!   cluster, *reactive* sandbox allocation, and a fixed inactivity
//!   timeout (15 min) for keeping sandboxes warm. The scheduler is a
//!   serial decision-maker: each placement costs decision time, so it
//!   saturates at high RPS — the §2.4 scalability critique.
//! * **Sparrow-style** (§2.4, Fig 2d): distributed schedulers place each
//!   task by probing `p` random workers (power-of-two-choices on queue
//!   length) and enqueueing at the shortest per-worker queue. Scales
//!   horizontally but is sandbox-oblivious: probes routinely land on
//!   workers without a warm sandbox.
//!
//! Both share the worker/sandbox substrate with Archipelago so the only
//! differences measured are the scheduling + sandbox policies.

use std::collections::{HashMap, VecDeque};

use crate::config::{Micros, SEC};
use crate::dag::{DagId, DagRegistry, FnId};
use crate::metrics::{Metrics, RequestOutcome, SummaryRow};
use crate::sgs::RequestId;
use crate::sim::{run_until, EventQueue};
use crate::util::rng::Rng;
use crate::worker::{Worker, WorkerId};
use crate::workload::App;

/// Which baseline to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaselineKind {
    /// Centralized FIFO + reactive sandboxes + inactivity timeout.
    CentralizedFifo,
    /// Sparrow-style probing with `probes` random samples per task.
    Sparrow { probes: usize },
}

/// Baseline knobs (§7.1 and Fig 2d parameters).
#[derive(Debug, Clone)]
pub struct BaselineOptions {
    pub kind: BaselineKind,
    pub seed: u64,
    pub horizon: Micros,
    pub warmup: Micros,
    /// Per-placement decision cost of the centralized scheduler
    /// (serialized; §7.4-comparable figure).
    pub decision_cost: Micros,
    /// Probe round-trip for Sparrow placement.
    pub probe_overhead: Micros,
    /// Keep-warm inactivity timeout (15 min on AWS/Azure [8, 10]).
    pub keep_warm_timeout: Micros,
    pub exec_noise_frac: f64,
}

impl Default for BaselineOptions {
    fn default() -> Self {
        BaselineOptions {
            kind: BaselineKind::CentralizedFifo,
            seed: 42,
            horizon: 60 * SEC,
            warmup: 5 * SEC,
            decision_cost: 241, // the paper's measured SGS decision time
            probe_overhead: 500,
            keep_warm_timeout: 15 * 60 * SEC,
            exec_noise_frac: 0.05,
        }
    }
}

/// One schedulable function instance.
#[derive(Debug, Clone)]
struct Task {
    req: RequestId,
    f: FnId,
    enqueued_at: Micros,
    exec_time: Micros,
    setup_time: Micros,
    mem_mb: u64,
}

#[derive(Debug)]
struct RequestState {
    dag: DagId,
    arrival: Micros,
    deadline_abs: Micros,
    pending_parents: Vec<u16>,
    remaining: usize,
    cold_starts: u32,
    exec_times: Vec<Micros>,
}

#[derive(Debug)]
enum Event {
    Arrival { app_idx: usize },
    /// Centralized: scheduler finished one decision; dispatch next.
    SchedulerTurn,
    /// Sparrow: task placed at a worker queue after the probe RTT.
    WorkerEnqueue { worker: usize, task: Task },
    FnComplete { worker: usize, req: RequestId, f: FnId },
    /// Periodic idle-sandbox sweep (keep-warm timeout enforcement).
    TimeoutSweep,
}

/// The baseline cluster simulator.
pub struct BaselineSim {
    opts: BaselineOptions,
    registry: DagRegistry,
    apps: Vec<App>,
    workers: Vec<Worker>,
    /// Centralized global FIFO.
    global_queue: VecDeque<Task>,
    /// Sparrow per-worker FIFO queues.
    worker_queues: Vec<VecDeque<Task>>,
    /// Centralized scheduler serialization: busy until this time.
    scheduler_free_at: Micros,
    scheduler_turn_pending: bool,
    requests: HashMap<u64, RequestState>,
    next_req: u64,
    events: EventQueue<Event>,
    pub metrics: Metrics,
    rng: Rng,
    cold_starts: u64,
    started: bool,
}

impl BaselineSim {
    pub fn new(
        total_workers: usize,
        cores_per_worker: u32,
        worker_mem_mb: u64,
        apps: Vec<App>,
        opts: BaselineOptions,
    ) -> Self {
        let mut registry = DagRegistry::new();
        let mut apps = apps;
        for app in apps.iter_mut() {
            let id = registry.register(app.dag.clone());
            app.dag.id = id;
        }
        BaselineSim {
            registry,
            apps,
            workers: (0..total_workers)
                .map(|i| Worker::new(WorkerId(i as u16), cores_per_worker, worker_mem_mb))
                .collect(),
            global_queue: VecDeque::new(),
            worker_queues: vec![VecDeque::new(); total_workers],
            scheduler_free_at: 0,
            scheduler_turn_pending: false,
            requests: HashMap::new(),
            next_req: 0,
            events: EventQueue::new(),
            metrics: Metrics::new(),
            rng: Rng::new(opts.seed),
            cold_starts: 0,
            opts,
            started: false,
        }
    }

    pub fn cold_starts(&self) -> u64 {
        self.cold_starts
    }

    pub fn run(&mut self) -> SummaryRow {
        if !self.started {
            self.started = true;
            for idx in 0..self.apps.len() {
                let first = self.apps[idx].arrivals.next_arrival(0, &mut self.rng);
                self.events.push_at(first, Event::Arrival { app_idx: idx });
            }
            self.events.push_at(SEC, Event::TimeoutSweep);
        }
        let horizon = self.opts.horizon;
        let mut queue = std::mem::take(&mut self.events);
        run_until(&mut queue, self, horizon, |q, sim, ev| sim.handle(q, ev));
        self.events = queue;
        self.metrics.summary_row()
    }

    fn handle(&mut self, q: &mut EventQueue<Event>, ev: Event) {
        match ev {
            Event::Arrival { app_idx } => self.on_arrival(q, app_idx),
            Event::SchedulerTurn => {
                self.scheduler_turn_pending = false;
                self.centralized_dispatch(q);
            }
            Event::WorkerEnqueue { worker, task } => {
                self.worker_queues[worker].push_back(task);
                self.worker_pump(q, worker);
            }
            Event::FnComplete { worker, req, f } => self.on_complete(q, worker, req, f),
            Event::TimeoutSweep => {
                self.sweep_idle_sandboxes(q.now());
                q.push_after(SEC, Event::TimeoutSweep);
            }
        }
    }

    fn on_arrival(&mut self, q: &mut EventQueue<Event>, app_idx: usize) {
        let now = q.now();
        let dag_id = self.apps[app_idx].dag.id;
        let dag = self.registry.get(dag_id);
        let req = RequestId(self.next_req);
        self.next_req += 1;
        let noise = self.opts.exec_noise_frac;
        let exec_times: Vec<Micros> = dag
            .functions
            .iter()
            .map(|f| {
                if noise > 0.0 {
                    ((f.exec_time as f64) * self.rng.range_f64(1.0 - noise, 1.0 + noise))
                        as Micros
                } else {
                    f.exec_time
                }
            })
            .collect();
        let state = RequestState {
            dag: dag_id,
            arrival: now,
            deadline_abs: now + dag.deadline,
            pending_parents: dag.parent_count.clone(),
            remaining: dag.len(),
            cold_starts: 0,
            exec_times,
        };
        let roots = dag.roots.clone();
        self.requests.insert(req.0, state);
        for root in roots {
            let task = self.make_task(req, dag_id, root, now);
            self.submit(q, task);
        }
        let next = self.apps[app_idx].arrivals.next_arrival(now, &mut self.rng);
        q.push_at(next, Event::Arrival { app_idx });
    }

    fn make_task(&self, req: RequestId, dag_id: DagId, fn_idx: u16, now: Micros) -> Task {
        let dag = self.registry.get(dag_id);
        let spec = &dag.functions[fn_idx as usize];
        Task {
            req,
            f: dag.fn_id(fn_idx),
            enqueued_at: now,
            exec_time: self.requests[&req.0].exec_times[fn_idx as usize],
            setup_time: spec.setup_time,
            mem_mb: spec.mem_mb,
        }
    }

    fn submit(&mut self, q: &mut EventQueue<Event>, task: Task) {
        match self.opts.kind {
            BaselineKind::CentralizedFifo => {
                self.global_queue.push_back(task);
                self.centralized_dispatch(q);
            }
            BaselineKind::Sparrow { probes } => {
                // power-of-p-choices on total queued work per worker
                let n = self.workers.len();
                let mut best: Option<(usize, usize)> = None; // (queue_len, idx)
                for _ in 0..probes.max(1) {
                    let w = self.rng.range_usize(0, n);
                    let qlen = self.worker_queues[w].len()
                        + (self.workers[w].cores_total() - self.workers[w].cores_free())
                            as usize;
                    if best.map_or(true, |(bq, _)| qlen < bq) {
                        best = Some((qlen, w));
                    }
                }
                let (_, w) = best.expect("probes >= 1");
                q.push_after(
                    self.opts.probe_overhead,
                    Event::WorkerEnqueue { worker: w, task },
                );
            }
        }
    }

    /// Centralized dispatch: one decision per `decision_cost`; FIFO order;
    /// OpenWhisk-style placement (global view).
    fn centralized_dispatch(&mut self, q: &mut EventQueue<Event>) {
        let now = q.now();
        if self.global_queue.is_empty() {
            return;
        }
        if self.scheduler_free_at > now {
            // scheduler busy: wake when free
            if !self.scheduler_turn_pending {
                self.scheduler_turn_pending = true;
                q.push_at(self.scheduler_free_at, Event::SchedulerTurn);
            }
            return;
        }
        // Find a worker with a free core (prefer warm sandbox, global view).
        let Some(task) = self.global_queue.front() else {
            return;
        };
        let pick = self.pick_worker_global(task);
        let Some(worker) = pick else {
            return; // no capacity: retry on next completion
        };
        let task = self.global_queue.pop_front().expect("checked front");
        self.scheduler_free_at = now + self.opts.decision_cost;
        let start = now + self.opts.decision_cost;
        self.start_task(q, worker, task, start);
        // Chain the next decision.
        if !self.global_queue.is_empty() && !self.scheduler_turn_pending {
            self.scheduler_turn_pending = true;
            q.push_at(self.scheduler_free_at, Event::SchedulerTurn);
        }
    }

    /// OpenWhisk-style placement: each function has a *home* worker
    /// (hash), used while it has a free core; under load the task spills
    /// to the next workers in hash order — usually a cold start there.
    /// This is the §2.4 "reactive, fixed, workload-unaware" behaviour:
    /// no demand estimation, no placement spreading.
    fn pick_worker_global(&self, task: &Task) -> Option<usize> {
        let n = self.workers.len();
        let home = {
            // splitmix-style hash of the function id
            let mut x = ((task.f.dag.0 as u64) << 16) ^ (task.f.idx as u64);
            x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            (x % n as u64) as usize
        };
        for off in 0..n {
            let i = (home + off) % n;
            let w = &self.workers[i];
            if !w.has_free_core() {
                continue;
            }
            if w.has_warm(task.f) || w.can_host_cold(task.mem_mb) {
                return Some(i);
            }
        }
        None
    }

    /// Sparrow worker pump: start queued tasks while cores are free.
    fn worker_pump(&mut self, q: &mut EventQueue<Event>, worker: usize) {
        let now = q.now();
        while self.workers[worker].has_free_core() {
            let Some(task) = self.worker_queues[worker].pop_front() else {
                break;
            };
            self.start_task(q, worker, task, now);
        }
    }

    /// Begin execution on `worker` at `start`: acquire a warm sandbox or
    /// pay the cold-start; LRU-evict idle sandboxes under memory pressure.
    fn start_task(&mut self, q: &mut EventQueue<Event>, worker: usize, task: Task, start: Micros) {
        let w = &mut self.workers[worker];
        let warm = w.has_warm(task.f);
        let setup = if warm {
            w.sandboxes.acquire_warm(task.f, start).expect("warm checked");
            0
        } else {
            // evict idle (LRU) sandboxes until the new one fits
            while !w.sandboxes.has_pool_mem(task.mem_mb) {
                let victim = w
                    .sandboxes
                    .evictable()
                    .min_by_key(|(_, _, _, last_used, _)| *last_used)
                    .map(|(f, _, _, _, _)| f);
                match victim {
                    Some(v) => {
                        w.sandboxes.hard_evict_one(v).expect("evictable");
                    }
                    None => break, // everything busy; overcommit below fails loudly
                }
            }
            w.sandboxes
                .acquire_cold(task.f, task.mem_mb, start)
                .expect("baseline worker memory exhausted by busy sandboxes");
            self.cold_starts += 1;
            if let Some(state) = self.requests.get_mut(&task.req.0) {
                state.cold_starts += 1;
            }
            task.setup_time
        };
        w.occupy_core();
        let qdelay = start.saturating_sub(task.enqueued_at);
        if start >= self.opts.warmup {
            self.metrics.record_qdelay(task.f.dag, qdelay);
        }
        q.push_at(
            start + setup + task.exec_time,
            Event::FnComplete {
                worker,
                req: task.req,
                f: task.f,
            },
        );
    }

    fn on_complete(&mut self, q: &mut EventQueue<Event>, worker: usize, req: RequestId, f: FnId) {
        let now = q.now();
        let w = &mut self.workers[worker];
        w.release_core();
        w.sandboxes.release(f, now).expect("busy sandbox");

        let mut finished = false;
        let mut ready: Vec<u16> = Vec::new();
        if let Some(state) = self.requests.get_mut(&req.0) {
            state.remaining -= 1;
            finished = state.remaining == 0;
            let dag = self.registry.get(state.dag);
            for &c in &dag.children[f.idx as usize] {
                state.pending_parents[c as usize] -= 1;
                if state.pending_parents[c as usize] == 0 {
                    ready.push(c);
                }
            }
        }
        if finished {
            let state = self.requests.remove(&req.0).expect("present");
            if now >= self.opts.warmup {
                self.metrics.record_completion(&RequestOutcome {
                    dag: state.dag,
                    arrival: state.arrival,
                    completion: now,
                    deadline_abs: state.deadline_abs,
                    cold_starts: state.cold_starts,
                });
            }
        } else {
            let dag_id = self.requests[&req.0].dag;
            for c in ready {
                let task = self.make_task(req, dag_id, c, now);
                self.submit(q, task);
            }
        }
        match self.opts.kind {
            BaselineKind::CentralizedFifo => self.centralized_dispatch(q),
            BaselineKind::Sparrow { .. } => self.worker_pump(q, worker),
        }
    }

    /// Enforce the fixed keep-warm timeout: hard-evict warm sandboxes
    /// idle longer than the timeout (§2.4's "static and workload-unaware
    /// policy").
    fn sweep_idle_sandboxes(&mut self, now: Micros) {
        let timeout = self.opts.keep_warm_timeout;
        for w in &mut self.workers {
            let stale: Vec<FnId> = w
                .sandboxes
                .evictable()
                .filter(|(_, _, _, last_used, _)| now.saturating_sub(*last_used) > timeout)
                .map(|(f, _, _, _, _)| f)
                .collect();
            for f in stale {
                while w.sandboxes.warm_idle(f) > 0 || w.sandboxes.soft(f) > 0 {
                    if w.sandboxes.hard_evict_one(f).is_err() {
                        break;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;
    use crate::dag::DagSpec;
    use crate::workload::{ArrivalProcess, DagClass};

    fn one_app(rate: f64, exec: Micros, setup: Micros, deadline: Micros) -> Vec<App> {
        let dag = DagSpec::single(DagId(0), "b", exec, setup, 128, deadline);
        vec![App {
            class: DagClass::C1,
            dag,
            arrivals: ArrivalProcess::constant(rate),
        }]
    }

    fn opts(kind: BaselineKind, horizon_s: u64) -> BaselineOptions {
        BaselineOptions {
            kind,
            horizon: horizon_s * SEC,
            warmup: 2 * SEC,
            exec_noise_frac: 0.0,
            ..BaselineOptions::default()
        }
    }

    #[test]
    fn centralized_completes_and_reuses_sandboxes() {
        let mut sim = BaselineSim::new(
            4,
            4,
            8 * 1024,
            one_app(50.0, 50 * MS, 200 * MS, 300 * MS),
            opts(BaselineKind::CentralizedFifo, 20),
        );
        let row = sim.run();
        assert!(row.completed > 700, "completed {}", row.completed);
        // reactive: the first wave is cold, then sandboxes are reused
        let cold_rate = sim.cold_starts() as f64 / row.completed as f64;
        assert!(cold_rate < 0.2, "cold rate {cold_rate}");
    }

    #[test]
    fn centralized_scheduler_is_a_throughput_bottleneck() {
        // 1/decision_cost = ~4100 decisions/s; offer 2000 rps on ample
        // cores: fine. Offer it with decision cost 2ms → max 500/s → queue
        // explodes and deadlines blow.
        let mut slow = opts(BaselineKind::CentralizedFifo, 10);
        slow.decision_cost = 2 * MS;
        let mut sim = BaselineSim::new(
            16,
            8,
            8 * 1024,
            one_app(1000.0, 20 * MS, 150 * MS, 200 * MS),
            slow,
        );
        let row = sim.run();
        assert!(
            row.deadline_met_rate < 0.5,
            "serialized scheduler should saturate: {}",
            row.deadline_met_rate
        );
    }

    #[test]
    fn sparrow_scales_where_centralized_chokes() {
        let mk = |kind| {
            let mut o = opts(kind, 10);
            o.decision_cost = 2 * MS;
            BaselineSim::new(
                16,
                8,
                8 * 1024,
                one_app(1000.0, 20 * MS, 150 * MS, 200 * MS),
                o,
            )
        };
        let mut sparrow = mk(BaselineKind::Sparrow { probes: 2 });
        let row_s = sparrow.run();
        let mut central = mk(BaselineKind::CentralizedFifo);
        let row_c = central.run();
        assert!(
            row_s.deadline_met_rate > row_c.deadline_met_rate + 0.2,
            "sparrow {} vs centralized {}",
            row_s.deadline_met_rate,
            row_c.deadline_met_rate
        );
    }

    #[test]
    fn sparrow_random_probing_costs_cold_starts() {
        // Archipelago-equivalent load on Sparrow: probes scatter tasks
        // across workers, so sandbox reuse is worse than a global view.
        let mut sim = BaselineSim::new(
            8,
            4,
            8 * 1024,
            one_app(100.0, 50 * MS, 200 * MS, 300 * MS),
            opts(BaselineKind::Sparrow { probes: 2 }, 20),
        );
        let row = sim.run();
        assert!(row.completed > 1500);
        assert!(sim.cold_starts() > 8, "scattering causes cold starts");
    }

    #[test]
    fn keep_warm_timeout_evicts_idle_sandboxes() {
        let mut o = opts(BaselineKind::CentralizedFifo, 30);
        o.keep_warm_timeout = 3 * SEC; // aggressive for the test
        // on/off: 5s on, 15s off → sandboxes die during off period
        let dag = DagSpec::single(DagId(0), "b", 20 * MS, 200 * MS, 128, 300 * MS);
        let apps = vec![App {
            class: DagClass::C1,
            dag,
            arrivals: ArrivalProcess::on_off(50.0, 5 * SEC, 15 * SEC),
        }];
        let mut sim = BaselineSim::new(2, 4, 4 * 1024, apps, o);
        let row = sim.run();
        // each on-period restarts cold
        assert!(
            sim.cold_starts() > 3,
            "timeout should force repeated cold starts: {}",
            sim.cold_starts()
        );
        assert!(row.completed > 100);
    }

    #[test]
    fn deterministic_per_seed() {
        let run = |seed| {
            let mut o = opts(BaselineKind::Sparrow { probes: 2 }, 10);
            o.seed = seed;
            let mut sim = BaselineSim::new(
                4,
                4,
                8 * 1024,
                one_app(100.0, 30 * MS, 200 * MS, 300 * MS),
                o,
            );
            let row = sim.run();
            (row.completed, row.p99, sim.cold_starts())
        };
        assert_eq!(run(3), run(3));
    }

    #[test]
    fn dag_requests_complete_on_baselines() {
        let dag = DagSpec::chain(
            DagId(0),
            "c",
            &[(20 * MS, 150 * MS, 128), (20 * MS, 150 * MS, 128)],
            500 * MS,
        );
        for kind in [BaselineKind::CentralizedFifo, BaselineKind::Sparrow { probes: 2 }] {
            let apps = vec![App {
                class: DagClass::C3,
                dag: dag.clone(),
                arrivals: ArrivalProcess::constant(30.0),
            }];
            let mut sim = BaselineSim::new(4, 4, 8 * 1024, apps, opts(kind, 10));
            let row = sim.run();
            assert!(row.completed > 150, "{kind:?}: {}", row.completed);
            assert!(row.p50 >= 40 * MS, "{kind:?}: p50 {}", row.p50);
        }
    }
}
