//! Platform configuration: cluster topology, scheduler policies,
//! estimator and scaling parameters — everything §7.1 fixes for the
//! testbed, exposed as a typed, validated, JSON-loadable config.
//!
//! Defaults reproduce the paper's deployment: 8 SGSs × 8 workers,
//! 20–28 cores and 256 GB per machine, proactive pool capped per worker,
//! `ScaleOutThreshold = 0.3`, sandbox setup 125–400 ms, estimation every
//! 100 ms at a 99% SLA.

use crate::util::json::{self, Json};

/// Microseconds — the platform-wide time unit.
pub type Micros = u64;

pub const MS: Micros = 1_000;
pub const SEC: Micros = 1_000_000;

/// Scheduling-queue policy inside an SGS (§4.2 vs baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Shortest-remaining-slack-first (the paper's policy).
    Srsf,
    /// First-in-first-out (baseline stack).
    Fifo,
}

/// Proactive sandbox placement across a worker pool (§4.3.2, Fig 4b/9).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Evenly spread sandboxes (min-count worker first) — the paper's.
    Even,
    /// Pack sandboxes onto as few workers as possible (ablation).
    Packed,
}

/// Hard-eviction victim selection (§4.3.3, §7.3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Evict from the function whose allocation most exceeds its
    /// estimate ("closest to its estimation" fairness metric).
    Fair,
    /// Least-recently-used sandbox (ablation; 4.62× worse tail in §7.3.1).
    Lru,
}

/// LBS scale-out behaviour (§5.2.3, §7.3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleOutMode {
    /// Lottery-weighted gradual ramp of the new SGS — the paper's.
    Gradual,
    /// Instant equal-share routing to all associated SGSs (ablation).
    Instant,
}

/// Cluster topology.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of semi-global schedulers (= worker pools).
    pub num_sgs: usize,
    /// Workers (machines) per SGS pool.
    pub workers_per_sgs: usize,
    /// CPU cores per worker available for function execution.
    pub cores_per_worker: u32,
    /// Total memory per worker (MB).
    pub worker_mem_mb: u64,
    /// Slice of each worker's memory reserved as the proactive
    /// sandbox pool (MB) — §4.3's "proactive memory pool".
    pub proactive_pool_mb: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // §7.1: 8 SGSs × 8 workers; 20–28 cores, 256 GB machines. We use
        // the conservative 20-core figure uniformly.
        ClusterConfig {
            num_sgs: 8,
            workers_per_sgs: 8,
            cores_per_worker: 20,
            worker_mem_mb: 256 * 1024,
            proactive_pool_mb: 32 * 1024,
        }
    }
}

/// SGS-side policy parameters (§4).
#[derive(Debug, Clone)]
pub struct SgsConfig {
    pub sched_policy: SchedPolicy,
    pub placement: PlacementPolicy,
    pub eviction: EvictionPolicy,
    /// Estimation interval T (§4.3.1; 100 ms in the prototype).
    pub estimate_interval: Micros,
    /// EWMA smoothing for the arrival-rate estimate.
    pub rate_ewma_alpha: f64,
    /// Provisioning SLA quantile fed to the Poisson inverse CDF.
    pub sla_quantile: f64,
    /// Headroom multiplier applied on top of the SLA-quantile demand
    /// (§4.3.1: "the SGS provisions sandboxes for the worst case load";
    /// Fig 8b shows allocations up to 37.4% above the ideal). Needed
    /// because warm sandboxes are spread over the pool while free cores
    /// are not — without headroom a burst lands on sandbox-less workers.
    pub provision_margin: f64,
    /// EWMA smoothing for per-DAG queuing delay reports (§5.2.1).
    pub qdelay_ewma_alpha: f64,
    /// Observations per queuing-delay window before the LBS may act.
    pub qdelay_window: usize,
    /// Per-request scheduling overhead added at the SGS (§7.4 measured
    /// median 241 µs on the Go prototype).
    pub sched_overhead: Micros,
}

impl Default for SgsConfig {
    fn default() -> Self {
        SgsConfig {
            sched_policy: SchedPolicy::Srsf,
            placement: PlacementPolicy::Even,
            eviction: EvictionPolicy::Fair,
            estimate_interval: 100 * MS,
            rate_ewma_alpha: 0.3,
            sla_quantile: 0.99,
            provision_margin: 0.35,
            qdelay_ewma_alpha: 0.3,
            qdelay_window: 16,
            sched_overhead: 241,
        }
    }
}

/// LBS-side parameters (§5).
#[derive(Debug, Clone)]
pub struct LbsConfig {
    /// Scale-out threshold on the normalized scaling metric (§7.5: 0.3).
    pub scale_out_threshold: f64,
    /// Scale-in threshold, kept well below SOT to avoid oscillation.
    pub scale_in_threshold: f64,
    /// Lottery-ticket discount for SGSs on the removed list.
    pub removed_discount: f64,
    /// Virtual nodes per SGS on the consistent-hash ring.
    pub ring_vnodes: usize,
    /// Per-request routing overhead added at the LBS (§7.4: 190 µs).
    pub route_overhead: Micros,
    /// How often the LBS evaluates scaling decisions.
    pub control_interval: Micros,
    pub scale_out_mode: ScaleOutMode,
}

impl Default for LbsConfig {
    fn default() -> Self {
        LbsConfig {
            scale_out_threshold: 0.3,
            scale_in_threshold: 0.05,
            removed_discount: 0.25,
            ring_vnodes: 32,
            route_overhead: 190,
            control_interval: 100 * MS,
            scale_out_mode: ScaleOutMode::Gradual,
        }
    }
}

/// Whole-platform configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub cluster: ClusterConfig,
    pub sgs: SgsConfig,
    pub lbs: LbsConfig,
}

#[derive(Debug)]
pub enum ConfigError {
    Invalid(String),
    Parse(String),
    Io(std::io::Error),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Invalid(m) => write!(f, "invalid config: {m}"),
            ConfigError::Parse(m) => write!(f, "config parse: {m}"),
            ConfigError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> Self {
        ConfigError::Io(e)
    }
}

impl Config {
    /// Validate invariants; every loader calls this.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let c = &self.cluster;
        let inv = |m: String| Err(ConfigError::Invalid(m));
        if c.num_sgs == 0 {
            return inv("num_sgs must be > 0".into());
        }
        if c.workers_per_sgs == 0 {
            return inv("workers_per_sgs must be > 0".into());
        }
        if c.cores_per_worker == 0 {
            return inv("cores_per_worker must be > 0".into());
        }
        if c.proactive_pool_mb > c.worker_mem_mb {
            return inv(format!(
                "proactive_pool_mb {} exceeds worker_mem_mb {}",
                c.proactive_pool_mb, c.worker_mem_mb
            ));
        }
        let s = &self.sgs;
        if !(0.0..=1.0).contains(&s.rate_ewma_alpha)
            || !(0.0..=1.0).contains(&s.qdelay_ewma_alpha)
        {
            return inv("EWMA alphas must be in [0, 1]".into());
        }
        if !(0.5..1.0).contains(&s.sla_quantile) {
            return inv("sla_quantile must be in [0.5, 1)".into());
        }
        if s.estimate_interval == 0 {
            return inv("estimate_interval must be > 0".into());
        }
        if s.qdelay_window == 0 {
            return inv("qdelay_window must be > 0".into());
        }
        let l = &self.lbs;
        if l.scale_in_threshold >= l.scale_out_threshold {
            return inv(format!(
                "scale_in_threshold {} must be < scale_out_threshold {}",
                l.scale_in_threshold, l.scale_out_threshold
            ));
        }
        if !(0.0..=1.0).contains(&l.removed_discount) {
            return inv("removed_discount must be in [0, 1]".into());
        }
        if l.ring_vnodes == 0 {
            return inv("ring_vnodes must be > 0".into());
        }
        Ok(())
    }

    /// Total cores across the cluster.
    pub fn total_cores(&self) -> u64 {
        self.cluster.num_sgs as u64
            * self.cluster.workers_per_sgs as u64
            * self.cluster.cores_per_worker as u64
    }

    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &str) -> Result<Config, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_str(&text)
    }

    pub fn from_json_str(text: &str) -> Result<Config, ConfigError> {
        let v = json::parse(text).map_err(|e| ConfigError::Parse(e.to_string()))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Config, ConfigError> {
        let mut cfg = Config::default();
        let perr = |m: String| ConfigError::Parse(m);
        if let Some(c) = v.get("cluster") {
            let cc = &mut cfg.cluster;
            read_usize(c, "num_sgs", &mut cc.num_sgs).map_err(perr)?;
            read_usize(c, "workers_per_sgs", &mut cc.workers_per_sgs).map_err(perr)?;
            read_u32(c, "cores_per_worker", &mut cc.cores_per_worker).map_err(perr)?;
            read_u64(c, "worker_mem_mb", &mut cc.worker_mem_mb).map_err(perr)?;
            read_u64(c, "proactive_pool_mb", &mut cc.proactive_pool_mb).map_err(perr)?;
        }
        if let Some(s) = v.get("sgs") {
            let sc = &mut cfg.sgs;
            if let Some(p) = s.get("sched_policy") {
                sc.sched_policy = match p.as_str() {
                    Some("srsf") => SchedPolicy::Srsf,
                    Some("fifo") => SchedPolicy::Fifo,
                    other => {
                        return Err(perr(format!("bad sched_policy {other:?}")));
                    }
                };
            }
            if let Some(p) = s.get("placement") {
                sc.placement = match p.as_str() {
                    Some("even") => PlacementPolicy::Even,
                    Some("packed") => PlacementPolicy::Packed,
                    other => return Err(perr(format!("bad placement {other:?}"))),
                };
            }
            if let Some(p) = s.get("eviction") {
                sc.eviction = match p.as_str() {
                    Some("fair") => EvictionPolicy::Fair,
                    Some("lru") => EvictionPolicy::Lru,
                    other => return Err(perr(format!("bad eviction {other:?}"))),
                };
            }
            read_u64(s, "estimate_interval_us", &mut sc.estimate_interval).map_err(perr)?;
            read_f64(s, "rate_ewma_alpha", &mut sc.rate_ewma_alpha).map_err(perr)?;
            read_f64(s, "sla_quantile", &mut sc.sla_quantile).map_err(perr)?;
            read_f64(s, "qdelay_ewma_alpha", &mut sc.qdelay_ewma_alpha).map_err(perr)?;
            read_usize(s, "qdelay_window", &mut sc.qdelay_window).map_err(perr)?;
            read_u64(s, "sched_overhead_us", &mut sc.sched_overhead).map_err(perr)?;
        }
        if let Some(l) = v.get("lbs") {
            let lc = &mut cfg.lbs;
            read_f64(l, "scale_out_threshold", &mut lc.scale_out_threshold).map_err(perr)?;
            read_f64(l, "scale_in_threshold", &mut lc.scale_in_threshold).map_err(perr)?;
            read_f64(l, "removed_discount", &mut lc.removed_discount).map_err(perr)?;
            read_usize(l, "ring_vnodes", &mut lc.ring_vnodes).map_err(perr)?;
            read_u64(l, "route_overhead_us", &mut lc.route_overhead).map_err(perr)?;
            read_u64(l, "control_interval_us", &mut lc.control_interval).map_err(perr)?;
            if let Some(p) = l.get("scale_out_mode") {
                lc.scale_out_mode = match p.as_str() {
                    Some("gradual") => ScaleOutMode::Gradual,
                    Some("instant") => ScaleOutMode::Instant,
                    other => return Err(perr(format!("bad scale_out_mode {other:?}"))),
                };
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Serialize the effective config (for run manifests / debugging).
    pub fn to_json(&self) -> Json {
        let c = &self.cluster;
        let s = &self.sgs;
        let l = &self.lbs;
        json::obj(vec![
            (
                "cluster",
                json::obj(vec![
                    ("num_sgs", Json::Int(c.num_sgs as i64)),
                    ("workers_per_sgs", Json::Int(c.workers_per_sgs as i64)),
                    ("cores_per_worker", Json::Int(c.cores_per_worker as i64)),
                    ("worker_mem_mb", Json::Int(c.worker_mem_mb as i64)),
                    ("proactive_pool_mb", Json::Int(c.proactive_pool_mb as i64)),
                ]),
            ),
            (
                "sgs",
                json::obj(vec![
                    (
                        "sched_policy",
                        Json::Str(
                            match s.sched_policy {
                                SchedPolicy::Srsf => "srsf",
                                SchedPolicy::Fifo => "fifo",
                            }
                            .into(),
                        ),
                    ),
                    (
                        "placement",
                        Json::Str(
                            match s.placement {
                                PlacementPolicy::Even => "even",
                                PlacementPolicy::Packed => "packed",
                            }
                            .into(),
                        ),
                    ),
                    (
                        "eviction",
                        Json::Str(
                            match s.eviction {
                                EvictionPolicy::Fair => "fair",
                                EvictionPolicy::Lru => "lru",
                            }
                            .into(),
                        ),
                    ),
                    ("estimate_interval_us", Json::Int(s.estimate_interval as i64)),
                    ("rate_ewma_alpha", Json::Num(s.rate_ewma_alpha)),
                    ("sla_quantile", Json::Num(s.sla_quantile)),
                    ("qdelay_ewma_alpha", Json::Num(s.qdelay_ewma_alpha)),
                    ("qdelay_window", Json::Int(s.qdelay_window as i64)),
                    ("sched_overhead_us", Json::Int(s.sched_overhead as i64)),
                ]),
            ),
            (
                "lbs",
                json::obj(vec![
                    ("scale_out_threshold", Json::Num(l.scale_out_threshold)),
                    ("scale_in_threshold", Json::Num(l.scale_in_threshold)),
                    ("removed_discount", Json::Num(l.removed_discount)),
                    ("ring_vnodes", Json::Int(l.ring_vnodes as i64)),
                    ("route_overhead_us", Json::Int(l.route_overhead as i64)),
                    ("control_interval_us", Json::Int(l.control_interval as i64)),
                    (
                        "scale_out_mode",
                        Json::Str(
                            match l.scale_out_mode {
                                ScaleOutMode::Gradual => "gradual",
                                ScaleOutMode::Instant => "instant",
                            }
                            .into(),
                        ),
                    ),
                ]),
            ),
        ])
    }
}

fn read_u64(v: &Json, key: &str, dst: &mut u64) -> Result<(), String> {
    if let Some(x) = v.get(key) {
        *dst = x
            .as_u64()
            .ok_or_else(|| format!("field '{key}' must be a non-negative integer"))?;
    }
    Ok(())
}

fn read_u32(v: &Json, key: &str, dst: &mut u32) -> Result<(), String> {
    let mut tmp = *dst as u64;
    read_u64(v, key, &mut tmp)?;
    *dst = u32::try_from(tmp).map_err(|_| format!("field '{key}' too large"))?;
    Ok(())
}

fn read_usize(v: &Json, key: &str, dst: &mut usize) -> Result<(), String> {
    let mut tmp = *dst as u64;
    read_u64(v, key, &mut tmp)?;
    *dst = tmp as usize;
    Ok(())
}

fn read_f64(v: &Json, key: &str, dst: &mut f64) -> Result<(), String> {
    if let Some(x) = v.get(key) {
        *dst = x
            .as_f64()
            .ok_or_else(|| format!("field '{key}' must be a number"))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_matches_paper_testbed() {
        let cfg = Config::default();
        cfg.validate().unwrap();
        assert_eq!(cfg.cluster.num_sgs, 8);
        assert_eq!(cfg.cluster.workers_per_sgs, 8);
        assert_eq!(cfg.lbs.scale_out_threshold, 0.3);
        assert_eq!(cfg.sgs.estimate_interval, 100 * MS);
        assert_eq!(cfg.total_cores(), 8 * 8 * 20);
    }

    #[test]
    fn json_roundtrip() {
        let cfg = Config::default();
        let text = cfg.to_json().to_pretty();
        let back = Config::from_json_str(&text).unwrap();
        assert_eq!(back.cluster.num_sgs, cfg.cluster.num_sgs);
        assert_eq!(back.sgs.sched_policy, cfg.sgs.sched_policy);
        assert_eq!(back.lbs.scale_out_threshold, cfg.lbs.scale_out_threshold);
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let cfg = Config::from_json_str(r#"{"cluster": {"num_sgs": 2}}"#).unwrap();
        assert_eq!(cfg.cluster.num_sgs, 2);
        assert_eq!(cfg.cluster.workers_per_sgs, 8);
    }

    #[test]
    fn policy_strings() {
        let cfg = Config::from_json_str(
            r#"{"sgs": {"sched_policy": "fifo", "placement": "packed", "eviction": "lru"},
                "lbs": {"scale_out_mode": "instant"}}"#,
        )
        .unwrap();
        assert_eq!(cfg.sgs.sched_policy, SchedPolicy::Fifo);
        assert_eq!(cfg.sgs.placement, PlacementPolicy::Packed);
        assert_eq!(cfg.sgs.eviction, EvictionPolicy::Lru);
        assert_eq!(cfg.lbs.scale_out_mode, ScaleOutMode::Instant);
        assert!(Config::from_json_str(r#"{"sgs": {"sched_policy": "lifo"}}"#).is_err());
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut cfg = Config::default();
        cfg.cluster.num_sgs = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = Config::default();
        cfg.cluster.proactive_pool_mb = cfg.cluster.worker_mem_mb + 1;
        assert!(cfg.validate().is_err());

        let mut cfg = Config::default();
        cfg.lbs.scale_in_threshold = 0.5; // >= SOT
        assert!(cfg.validate().is_err());

        let mut cfg = Config::default();
        cfg.sgs.sla_quantile = 1.0;
        assert!(cfg.validate().is_err());
    }
}
