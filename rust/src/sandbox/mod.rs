//! Sandbox lifecycle + per-worker sandbox accounting (§4.3, Fig 4c).
//!
//! A sandbox passes through: **setting-up** (container launch + runtime +
//! code fetch; 125–400 ms) → **warm-idle** (ready, schedulable) ⇄ **busy**
//! (running a request) → warm-idle, with two eviction stages: **soft**
//! (excluded from scheduling, still memory-resident, revivable for free —
//! §4.3.3) and **hard** (memory released). Proactively allocated
//! sandboxes are *soft state*: they only consume memory from a fixed-size
//! per-worker pool and can be dropped without correctness impact.
//!
//! [`SandboxTable`] tracks one worker's sandboxes as per-function counts —
//! sandboxes of the same function are fungible, so counts (not objects)
//! keep the hot path allocation-free.

use crate::util::fasthash::FastMap;

use crate::config::Micros;
use crate::dag::FnId;

/// Per-function sandbox counts on one worker.
#[derive(Debug, Clone, Default)]
pub struct SandboxSet {
    /// Memory per sandbox of this function (MB).
    pub mem_mb: u64,
    /// Being set up (proactive allocation in flight).
    pub setting_up: u32,
    /// Warm and idle — schedulable.
    pub warm_idle: u32,
    /// Currently executing a request.
    pub busy: u32,
    /// Soft-evicted: memory-resident, not schedulable, free to revive.
    pub soft: u32,
    /// Virtual time of last use (LRU eviction ablation).
    pub last_used: Micros,
}

impl SandboxSet {
    /// Sandboxes that count against the demand target (schedulable or
    /// about to be): setting_up + warm + busy.
    pub fn active(&self) -> u32 {
        self.setting_up + self.warm_idle + self.busy
    }

    /// Everything occupying pool memory.
    pub fn resident(&self) -> u32 {
        self.active() + self.soft
    }

    pub fn mem_used_mb(&self) -> u64 {
        self.resident() as u64 * self.mem_mb
    }
}

/// Errors from sandbox-table operations — these indicate caller bugs in
/// the scheduler, so they're loud.
#[derive(Debug, PartialEq)]
pub enum SandboxError {
    NoWarm(FnId),
    NoneInState(FnId, &'static str),
    PoolExhausted { need: u64, free: u64 },
}

impl std::fmt::Display for SandboxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SandboxError::NoWarm(id) => write!(f, "no warm sandbox of {id:?} to acquire"),
            SandboxError::NoneInState(id, state) => {
                write!(f, "no sandbox of {id:?} in state {state}")
            }
            SandboxError::PoolExhausted { need, free } => {
                write!(f, "pool exhausted: need {need} MB, free {free} MB")
            }
        }
    }
}

impl std::error::Error for SandboxError {}

/// One worker's sandbox table + proactive memory pool accounting.
#[derive(Debug, Clone)]
pub struct SandboxTable {
    pool_total_mb: u64,
    pool_used_mb: u64,
    sets: FastMap<FnId, SandboxSet>,
}

impl SandboxTable {
    pub fn new(pool_total_mb: u64) -> Self {
        SandboxTable {
            pool_total_mb,
            pool_used_mb: 0,
            sets: FastMap::default(),
        }
    }

    pub fn pool_free_mb(&self) -> u64 {
        self.pool_total_mb - self.pool_used_mb
    }

    pub fn pool_used_mb(&self) -> u64 {
        self.pool_used_mb
    }

    pub fn pool_total_mb(&self) -> u64 {
        self.pool_total_mb
    }

    pub fn get(&self, f: FnId) -> Option<&SandboxSet> {
        self.sets.get(&f)
    }

    /// Active (schedulable-or-pending) count for a function.
    pub fn active(&self, f: FnId) -> u32 {
        self.sets.get(&f).map(|s| s.active()).unwrap_or(0)
    }

    pub fn warm_idle(&self, f: FnId) -> u32 {
        self.sets.get(&f).map(|s| s.warm_idle).unwrap_or(0)
    }

    pub fn soft(&self, f: FnId) -> u32 {
        self.sets.get(&f).map(|s| s.soft).unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&FnId, &SandboxSet)> {
        self.sets.iter()
    }

    fn entry(&mut self, f: FnId, mem_mb: u64) -> &mut SandboxSet {
        let e = self.sets.entry(f).or_default();
        e.mem_mb = mem_mb;
        e
    }

    /// Can a new sandbox of `mem_mb` be created without eviction?
    pub fn has_pool_mem(&self, mem_mb: u64) -> bool {
        self.pool_free_mb() >= mem_mb
    }

    /// Start proactive setup of one sandbox (caller adds the setup-time
    /// event and later calls [`finish_setup`](Self::finish_setup)).
    pub fn begin_setup(&mut self, f: FnId, mem_mb: u64) -> Result<(), SandboxError> {
        if !self.has_pool_mem(mem_mb) {
            return Err(SandboxError::PoolExhausted {
                need: mem_mb,
                free: self.pool_free_mb(),
            });
        }
        self.pool_used_mb += mem_mb;
        self.entry(f, mem_mb).setting_up += 1;
        Ok(())
    }

    /// Setup finished: sandbox becomes warm.
    pub fn finish_setup(&mut self, f: FnId) -> Result<(), SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .filter(|s| s.setting_up > 0)
            .ok_or(SandboxError::NoneInState(f, "setting_up"))?;
        s.setting_up -= 1;
        s.warm_idle += 1;
        Ok(())
    }

    /// Claim a warm sandbox for execution.
    pub fn acquire_warm(&mut self, f: FnId, now: Micros) -> Result<(), SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .filter(|s| s.warm_idle > 0)
            .ok_or(SandboxError::NoWarm(f))?;
        s.warm_idle -= 1;
        s.busy += 1;
        s.last_used = now;
        Ok(())
    }

    /// Reactive (cold) allocation straight into busy: the request pays
    /// the setup time, modeled by the caller. Takes pool memory.
    pub fn acquire_cold(&mut self, f: FnId, mem_mb: u64, now: Micros) -> Result<(), SandboxError> {
        if !self.has_pool_mem(mem_mb) {
            return Err(SandboxError::PoolExhausted {
                need: mem_mb,
                free: self.pool_free_mb(),
            });
        }
        self.pool_used_mb += mem_mb;
        let s = self.entry(f, mem_mb);
        s.busy += 1;
        s.last_used = now;
        Ok(())
    }

    /// Execution finished: busy → warm-idle (sandboxes are reused).
    pub fn release(&mut self, f: FnId, now: Micros) -> Result<(), SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .filter(|s| s.busy > 0)
            .ok_or(SandboxError::NoneInState(f, "busy"))?;
        s.busy -= 1;
        s.warm_idle += 1;
        s.last_used = now;
        Ok(())
    }

    /// Soft-evict one warm sandbox (demand decreased; §4.3.3).
    pub fn soft_evict_one(&mut self, f: FnId) -> Result<(), SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .filter(|s| s.warm_idle > 0)
            .ok_or(SandboxError::NoneInState(f, "warm_idle"))?;
        s.warm_idle -= 1;
        s.soft += 1;
        Ok(())
    }

    /// Revive a soft-evicted sandbox — free, no overhead (§4.3.3).
    pub fn soft_revive_one(&mut self, f: FnId) -> Result<(), SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .filter(|s| s.soft > 0)
            .ok_or(SandboxError::NoneInState(f, "soft"))?;
        s.soft -= 1;
        s.warm_idle += 1;
        Ok(())
    }

    /// Hard-evict one sandbox of `f`, preferring soft-evicted ones, then
    /// warm-idle. Busy / setting-up sandboxes are never evicted.
    /// Releases pool memory.
    pub fn hard_evict_one(&mut self, f: FnId) -> Result<u64, SandboxError> {
        let s = self
            .sets
            .get_mut(&f)
            .ok_or(SandboxError::NoneInState(f, "any"))?;
        if s.soft > 0 {
            s.soft -= 1;
        } else if s.warm_idle > 0 {
            s.warm_idle -= 1;
        } else {
            return Err(SandboxError::NoneInState(f, "evictable"));
        }
        let mem = s.mem_mb;
        self.pool_used_mb -= mem;
        if s.resident() == 0 {
            self.sets.remove(&f);
        }
        Ok(mem)
    }

    /// Candidates for hard eviction: (fn, evictable_count, mem_mb,
    /// last_used, soft_count). Used by the eviction policies.
    pub fn evictable(&self) -> impl Iterator<Item = (FnId, u32, u64, Micros, u32)> + '_ {
        self.sets.iter().filter_map(|(f, s)| {
            let evictable = s.soft + s.warm_idle;
            (evictable > 0).then_some((*f, evictable, s.mem_mb, s.last_used, s.soft))
        })
    }

    /// Accounting invariant: pool_used equals the sum of resident
    /// sandbox memory. Property tests drive this.
    pub fn check_invariants(&self) -> Result<(), String> {
        let sum: u64 = self.sets.values().map(|s| s.mem_used_mb()).sum();
        if sum != self.pool_used_mb {
            return Err(format!(
                "pool accounting drift: sum {sum} != used {}",
                self.pool_used_mb
            ));
        }
        if self.pool_used_mb > self.pool_total_mb {
            return Err(format!(
                "pool overcommitted: {} > {}",
                self.pool_used_mb, self.pool_total_mb
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dag::DagId;

    fn fid(i: u16) -> FnId {
        FnId {
            dag: DagId(0),
            idx: i,
        }
    }

    #[test]
    fn lifecycle_setup_warm_busy_release() {
        let mut t = SandboxTable::new(1024);
        t.begin_setup(fid(0), 128).unwrap();
        assert_eq!(t.pool_used_mb(), 128);
        assert_eq!(t.active(fid(0)), 1);
        assert_eq!(t.warm_idle(fid(0)), 0);
        t.finish_setup(fid(0)).unwrap();
        assert_eq!(t.warm_idle(fid(0)), 1);
        t.acquire_warm(fid(0), 100).unwrap();
        assert_eq!(t.warm_idle(fid(0)), 0);
        assert_eq!(t.get(fid(0)).unwrap().busy, 1);
        t.release(fid(0), 200).unwrap();
        assert_eq!(t.warm_idle(fid(0)), 1);
        assert_eq!(t.get(fid(0)).unwrap().last_used, 200);
        t.check_invariants().unwrap();
    }

    #[test]
    fn cold_acquire_counts_memory() {
        let mut t = SandboxTable::new(256);
        t.acquire_cold(fid(1), 128, 5).unwrap();
        assert_eq!(t.pool_used_mb(), 128);
        assert_eq!(t.active(fid(1)), 1);
        t.release(fid(1), 10).unwrap();
        assert_eq!(t.warm_idle(fid(1)), 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn pool_exhaustion() {
        let mut t = SandboxTable::new(200);
        t.begin_setup(fid(0), 128).unwrap();
        assert_eq!(
            t.begin_setup(fid(1), 128).unwrap_err(),
            SandboxError::PoolExhausted { need: 128, free: 72 }
        );
        assert!(!t.has_pool_mem(128));
        assert!(t.has_pool_mem(72));
    }

    #[test]
    fn soft_evict_revive_roundtrip_free() {
        let mut t = SandboxTable::new(1024);
        t.begin_setup(fid(0), 128).unwrap();
        t.finish_setup(fid(0)).unwrap();
        t.soft_evict_one(fid(0)).unwrap();
        assert_eq!(t.warm_idle(fid(0)), 0);
        assert_eq!(t.soft(fid(0)), 1);
        // memory still held
        assert_eq!(t.pool_used_mb(), 128);
        t.soft_revive_one(fid(0)).unwrap();
        assert_eq!(t.warm_idle(fid(0)), 1);
        t.check_invariants().unwrap();
    }

    #[test]
    fn hard_evict_prefers_soft_and_frees_memory() {
        let mut t = SandboxTable::new(1024);
        for _ in 0..2 {
            t.begin_setup(fid(0), 128).unwrap();
            t.finish_setup(fid(0)).unwrap();
        }
        t.soft_evict_one(fid(0)).unwrap();
        assert_eq!((t.warm_idle(fid(0)), t.soft(fid(0))), (1, 1));
        let freed = t.hard_evict_one(fid(0)).unwrap();
        assert_eq!(freed, 128);
        // the soft one went first
        assert_eq!((t.warm_idle(fid(0)), t.soft(fid(0))), (1, 0));
        assert_eq!(t.pool_used_mb(), 128);
        t.hard_evict_one(fid(0)).unwrap();
        assert_eq!(t.pool_used_mb(), 0);
        assert!(t.get(fid(0)).is_none(), "empty set is removed");
        t.check_invariants().unwrap();
    }

    #[test]
    fn busy_sandboxes_not_evictable() {
        let mut t = SandboxTable::new(1024);
        t.acquire_cold(fid(0), 128, 0).unwrap();
        assert_eq!(
            t.hard_evict_one(fid(0)).unwrap_err(),
            SandboxError::NoneInState(fid(0), "evictable")
        );
        assert_eq!(t.evictable().count(), 0);
    }

    #[test]
    fn error_paths() {
        let mut t = SandboxTable::new(1024);
        assert!(t.acquire_warm(fid(0), 0).is_err());
        assert!(t.release(fid(0), 0).is_err());
        assert!(t.finish_setup(fid(0)).is_err());
        assert!(t.soft_evict_one(fid(0)).is_err());
        assert!(t.soft_revive_one(fid(0)).is_err());
    }

    #[test]
    fn evictable_listing() {
        let mut t = SandboxTable::new(1024);
        t.begin_setup(fid(0), 128).unwrap();
        t.finish_setup(fid(0)).unwrap();
        t.begin_setup(fid(1), 64).unwrap(); // still setting up
        let ev: Vec<_> = t.evictable().collect();
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].0, fid(0));
        assert_eq!(ev[0].1, 1);
    }
}
