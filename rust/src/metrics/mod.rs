//! Metrics collection + reporting (§7.1 "Metrics").
//!
//! Tracks the paper's four evaluation quantities per DAG class and
//! globally: end-to-end latency, % deadlines met, queuing delay, and
//! cold-start counts — plus time series for the figure harnesses
//! (per-interval deadline-met rates for Fig 9, sandbox counts for
//! Fig 8b/10/11). Latency distributions use the log-bucketed histogram
//! so multi-million-request runs stay constant-memory.

use std::collections::BTreeMap;

use crate::config::{Micros, SEC};
use crate::dag::DagId;
use crate::util::json::{self, Json};
use crate::util::stats::LogHistogram;

/// Outcome of a single completed request.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub dag: DagId,
    pub arrival: Micros,
    pub completion: Micros,
    pub deadline_abs: Micros,
    /// Cold starts among this request's function executions.
    pub cold_starts: u32,
}

impl RequestOutcome {
    pub fn e2e_latency(&self) -> Micros {
        self.completion.saturating_sub(self.arrival)
    }

    pub fn deadline_met(&self) -> bool {
        self.completion <= self.deadline_abs
    }
}

/// Aggregated stats for one group (a DAG, a class, or the whole run).
#[derive(Debug, Clone)]
pub struct GroupStats {
    pub e2e: LogHistogram,
    pub qdelay: LogHistogram,
    pub completed: u64,
    pub deadlines_met: u64,
    pub cold_starts: u64,
    /// Requests that finished their scheduling lifecycle but whose
    /// execution failed (executor error). A failed request stays in
    /// `completed` (its latency sample is real) but never counts in
    /// `deadlines_met` — see [`Metrics::record_failure`].
    pub failed: u64,
}

impl Default for GroupStats {
    fn default() -> Self {
        GroupStats {
            e2e: LogHistogram::new(),
            qdelay: LogHistogram::new(),
            completed: 0,
            deadlines_met: 0,
            cold_starts: 0,
            failed: 0,
        }
    }
}

impl GroupStats {
    /// Fold another group's counters and histograms into this one.
    /// Exact: histogram buckets and counters add, so a merge of
    /// per-shard stats equals the stats a single global collector would
    /// have produced, regardless of merge order.
    pub fn merge(&mut self, other: &GroupStats) {
        self.e2e.merge(&other.e2e);
        self.qdelay.merge(&other.qdelay);
        self.completed += other.completed;
        self.deadlines_met += other.deadlines_met;
        self.cold_starts += other.cold_starts;
        self.failed += other.failed;
    }

    pub fn deadline_met_rate(&self) -> f64 {
        if self.completed == 0 {
            return 1.0;
        }
        self.deadlines_met as f64 / self.completed as f64
    }

    pub fn miss_rate(&self) -> f64 {
        1.0 - self.deadline_met_rate()
    }

    /// The shared deadline-attainment / tail-percentile summary: the
    /// paper's headline quantities for one group, computed once here so
    /// the sim `SummaryRow` path and the loadgen report cannot drift.
    /// Percentiles come from the log-bucketed e2e histogram (bucket low
    /// edge, clamped to the observed min/max — see
    /// [`LogHistogram::quantile`]).
    pub fn attainment_summary(&self) -> AttainmentSummary {
        let (p50, _, p99, p999, max) = self.e2e.tail_summary();
        AttainmentSummary {
            completed: self.completed,
            failed: self.failed,
            attainment: self.deadline_met_rate(),
            p50,
            p99,
            p999,
            max,
        }
    }
}

/// Deadline-attainment fraction + tail percentiles for one stats group —
/// the quantity set behind the paper's ">99% of requests meet their
/// deadline" claim. Produced by [`GroupStats::attainment_summary`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttainmentSummary {
    pub completed: u64,
    pub failed: u64,
    /// `deadlines_met / completed`; 1.0 for an empty group. Failed
    /// requests count against attainment (they are in `completed` but
    /// never in `deadlines_met`).
    pub attainment: f64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
}

/// The run-wide collector.
#[derive(Debug, Default)]
pub struct Metrics {
    pub total: GroupStats,
    pub per_dag: BTreeMap<u32, GroupStats>,
    /// Per-interval (deadline-met, completed) counts for Fig 9-style
    /// interval plots; interval length set by `interval_len`.
    interval_len: Micros,
    intervals: Vec<(u64, u64)>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            interval_len: SEC,
            ..Default::default()
        }
    }

    pub fn with_interval(interval_len: Micros) -> Self {
        Metrics {
            interval_len,
            ..Default::default()
        }
    }

    /// Record a completed request.
    pub fn record_completion(&mut self, outcome: &RequestOutcome) {
        let lat = outcome.e2e_latency();
        let met = outcome.deadline_met();
        for g in [
            &mut self.total,
            self.per_dag.entry(outcome.dag.0).or_default(),
        ] {
            g.e2e.record(lat);
            g.completed += 1;
            g.deadlines_met += u64::from(met);
            g.cold_starts += u64::from(outcome.cold_starts);
        }
        let idx = (outcome.completion / self.interval_len) as usize;
        if self.intervals.len() <= idx {
            self.intervals.resize(idx + 1, (0, 0));
        }
        self.intervals[idx].0 += u64::from(met);
        self.intervals[idx].1 += 1;
    }

    /// Fold another collector into this one (the sharded coordinator's
    /// read path: each shard records into its own `Metrics`, merged on
    /// demand). Commutative and associative, with the empty collector
    /// as identity — both merge orders yield identical summaries. The
    /// two collectors must use the same `interval_len`; an empty
    /// collector adopts the other's.
    pub fn merge(&mut self, other: &Metrics) {
        debug_assert!(
            self.interval_len == 0
                || other.interval_len == 0
                || self.interval_len == other.interval_len,
            "merging metrics with different interval lengths"
        );
        if self.interval_len == 0 {
            self.interval_len = other.interval_len;
        }
        self.total.merge(&other.total);
        for (id, g) in &other.per_dag {
            self.per_dag.entry(*id).or_default().merge(g);
        }
        if self.intervals.len() < other.intervals.len() {
            self.intervals.resize(other.intervals.len(), (0, 0));
        }
        for (i, &(met, n)) in other.intervals.iter().enumerate() {
            self.intervals[i].0 += met;
            self.intervals[i].1 += n;
        }
    }

    /// Reclassify an already-recorded completion as *failed* (executor
    /// error). The latency sample stays — the request really did occupy
    /// the platform end-to-end — but a failed request can never count
    /// as having met its deadline, so the timing-based `deadlines_met`
    /// credit (and its interval entry) is taken back. Call with the
    /// same `outcome` that was passed to [`Metrics::record_completion`].
    pub fn record_failure(&mut self, outcome: &RequestOutcome) {
        let met = outcome.deadline_met();
        for g in [
            &mut self.total,
            self.per_dag.entry(outcome.dag.0).or_default(),
        ] {
            g.failed += 1;
            if met {
                g.deadlines_met = g.deadlines_met.saturating_sub(1);
            }
        }
        if met && self.interval_len > 0 {
            let idx = (outcome.completion / self.interval_len) as usize;
            if let Some(iv) = self.intervals.get_mut(idx) {
                iv.0 = iv.0.saturating_sub(1);
            }
        }
    }

    /// Record one function's queuing delay.
    pub fn record_qdelay(&mut self, dag: DagId, delay: Micros) {
        self.total.qdelay.record(delay);
        self.per_dag.entry(dag.0).or_default().qdelay.record(delay);
    }

    pub fn dag(&self, dag: DagId) -> Option<&GroupStats> {
        self.per_dag.get(&dag.0)
    }

    /// Per-interval deadline-met fractions (Fig 9 series).
    pub fn interval_met_rates(&self) -> Vec<f64> {
        self.intervals
            .iter()
            .map(|&(met, n)| if n == 0 { 1.0 } else { met as f64 / n as f64 })
            .collect()
    }

    /// The paper's headline row: p50/p90/p99/p999/max E2E latency (µs),
    /// % deadlines met, cold starts.
    pub fn summary_row(&self) -> SummaryRow {
        let att = self.total.attainment_summary();
        SummaryRow {
            completed: att.completed,
            p50: att.p50,
            p90: self.total.e2e.quantile(0.90),
            p99: att.p99,
            p999: att.p999,
            max: att.max,
            deadline_met_rate: att.attainment,
            cold_starts: self.total.cold_starts,
            failed: att.failed,
            qdelay_p50: self.total.qdelay.quantile(0.5),
            qdelay_p99: self.total.qdelay.quantile(0.99),
            qdelay_p999: self.total.qdelay.quantile(0.999),
        }
    }

    pub fn to_json(&self) -> Json {
        let row = self.summary_row();
        let mut per_dag = Vec::new();
        for (id, g) in &self.per_dag {
            let (p50, _, p99, p999, max) = g.e2e.tail_summary();
            per_dag.push(json::obj(vec![
                ("dag", Json::Int(*id as i64)),
                ("completed", Json::Int(g.completed as i64)),
                ("p50_us", Json::Int(p50 as i64)),
                ("p99_us", Json::Int(p99 as i64)),
                ("p999_us", Json::Int(p999 as i64)),
                ("max_us", Json::Int(max as i64)),
                ("deadline_met_rate", Json::Num(g.deadline_met_rate())),
                ("cold_starts", Json::Int(g.cold_starts as i64)),
            ]));
        }
        json::obj(vec![
            ("completed", Json::Int(row.completed as i64)),
            ("p50_us", Json::Int(row.p50 as i64)),
            ("p90_us", Json::Int(row.p90 as i64)),
            ("p99_us", Json::Int(row.p99 as i64)),
            ("p999_us", Json::Int(row.p999 as i64)),
            ("max_us", Json::Int(row.max as i64)),
            ("deadline_met_rate", Json::Num(row.deadline_met_rate)),
            ("cold_starts", Json::Int(row.cold_starts as i64)),
            ("failed", Json::Int(row.failed as i64)),
            ("qdelay_p50_us", Json::Int(row.qdelay_p50 as i64)),
            ("qdelay_p99_us", Json::Int(row.qdelay_p99 as i64)),
            ("per_dag", Json::Arr(per_dag)),
        ])
    }
}

/// Flat summary used by reports and EXPERIMENTS.md tables.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SummaryRow {
    pub completed: u64,
    pub p50: u64,
    pub p90: u64,
    pub p99: u64,
    pub p999: u64,
    pub max: u64,
    pub deadline_met_rate: f64,
    pub cold_starts: u64,
    /// Completed requests whose execution failed (always 0 in the
    /// simulator; the real-time driver records executor errors here).
    pub failed: u64,
    pub qdelay_p50: u64,
    pub qdelay_p99: u64,
    pub qdelay_p999: u64,
}

impl SummaryRow {
    pub fn format_line(&self, label: &str) -> String {
        let mut line = format!(
            "{label:<22} n={:<9} p50={:<9} p99={:<10} p99.9={:<10} max={:<10} met={:>6.2}%  cold={}",
            self.completed,
            fmt_us(self.p50),
            fmt_us(self.p99),
            fmt_us(self.p999),
            fmt_us(self.max),
            self.deadline_met_rate * 100.0,
            self.cold_starts,
        );
        if self.failed > 0 {
            line.push_str(&format!("  failed={}", self.failed));
        }
        line
    }
}

/// Render microseconds with adaptive units.
pub fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{us}µs")
    } else if us < 1_000_000 {
        format!("{:.2}ms", us as f64 / 1_000.0)
    } else {
        format!("{:.2}s", us as f64 / 1_000_000.0)
    }
}

/// A simple CSV writer for the figure harnesses.
#[derive(Debug, Default)]
pub struct Csv {
    rows: Vec<String>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Self {
        Csv {
            rows: vec![header.join(",")],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.join(","));
    }

    pub fn to_string(&self) -> String {
        self.rows.join("\n") + "\n"
    }

    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn outcome(dag: u32, arrival: Micros, lat: Micros, deadline: Micros, cold: u32) -> RequestOutcome {
        RequestOutcome {
            dag: DagId(dag),
            arrival,
            completion: arrival + lat,
            deadline_abs: arrival + deadline,
            cold_starts: cold,
        }
    }

    #[test]
    fn outcome_latency_and_deadline() {
        let o = outcome(0, 100, 50, 80, 1);
        assert_eq!(o.e2e_latency(), 50);
        assert!(o.deadline_met());
        let o = outcome(0, 100, 90, 80, 0);
        assert!(!o.deadline_met());
    }

    #[test]
    fn aggregation_total_and_per_dag() {
        let mut m = Metrics::new();
        m.record_completion(&outcome(0, 0, 10 * MS, 20 * MS, 1));
        m.record_completion(&outcome(0, 0, 30 * MS, 20 * MS, 0));
        m.record_completion(&outcome(1, 0, 5 * MS, 20 * MS, 0));
        assert_eq!(m.total.completed, 3);
        assert_eq!(m.total.deadlines_met, 2);
        assert_eq!(m.total.cold_starts, 1);
        assert_eq!(m.dag(DagId(0)).unwrap().completed, 2);
        assert!((m.dag(DagId(0)).unwrap().deadline_met_rate() - 0.5).abs() < 1e-9);
        assert_eq!(m.dag(DagId(1)).unwrap().completed, 1);
    }

    #[test]
    fn interval_series() {
        let mut m = Metrics::with_interval(SEC);
        // second 0: 2 met; second 2: 1 missed
        m.record_completion(&outcome(0, 0, 10 * MS, 20 * MS, 0));
        m.record_completion(&outcome(0, 100 * MS, 10 * MS, 20 * MS, 0));
        m.record_completion(&outcome(0, 2 * SEC, 50 * MS, 20 * MS, 0));
        let rates = m.interval_met_rates();
        assert_eq!(rates.len(), 3);
        assert_eq!(rates[0], 1.0);
        assert_eq!(rates[1], 1.0, "empty interval counts as met");
        assert_eq!(rates[2], 0.0);
    }

    #[test]
    fn summary_row_and_json() {
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_completion(&outcome(0, 0, i * MS, 200 * MS, 0));
        }
        m.record_qdelay(DagId(0), 500);
        let row = m.summary_row();
        assert_eq!(row.completed, 100);
        assert!(row.p50 >= 45 * MS && row.p50 <= 55 * MS, "{}", row.p50);
        assert!(row.p99 >= 95 * MS, "{}", row.p99);
        assert_eq!(row.deadline_met_rate, 1.0);
        let j = m.to_json();
        assert_eq!(j.get("completed").unwrap().as_i64(), Some(100));
        assert!(j.get("per_dag").unwrap().as_arr().unwrap().len() == 1);
        assert!(row.format_line("test").contains("met=100.00%"));
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut m = Metrics::new();
        for i in 1..=50u64 {
            m.record_completion(&outcome(0, i * SEC / 10, i * MS, 200 * MS, i as u32 % 2));
            m.record_qdelay(DagId(0), i * 100);
        }
        let before = m.summary_row();
        let rates_before = m.interval_met_rates();

        // identity on the right: m ∪ ∅ = m
        m.merge(&Metrics::new());
        assert_eq!(m.summary_row(), before);
        assert_eq!(m.interval_met_rates(), rates_before);

        // identity on the left: ∅ ∪ m = m
        let mut empty = Metrics::new();
        empty.merge(&m);
        assert_eq!(empty.summary_row(), before);
        assert_eq!(empty.interval_met_rates(), rates_before);
        assert_eq!(empty.dag(DagId(0)).unwrap().completed, 50);
    }

    #[test]
    fn merge_is_order_independent_and_matches_global_collector() {
        // Record the same outcome stream (a) into one global collector
        // and (b) split across two "shards", then merge both ways: all
        // three must agree field-for-field, percentiles and interval
        // rates included.
        let outcomes: Vec<RequestOutcome> = (1..=200u64)
            .map(|i| {
                outcome(
                    (i % 3) as u32,
                    i * SEC / 20,
                    (i * i * 7) % (500 * MS) + 1,
                    100 * MS,
                    (i % 4) as u32,
                )
            })
            .collect();
        let mut global = Metrics::new();
        let mut shard_a = Metrics::new();
        let mut shard_b = Metrics::new();
        for (i, o) in outcomes.iter().enumerate() {
            global.record_completion(o);
            global.record_qdelay(o.dag, (i as u64 * 31) % 10_000);
            let shard = if i % 2 == 0 { &mut shard_a } else { &mut shard_b };
            shard.record_completion(o);
            shard.record_qdelay(o.dag, (i as u64 * 31) % 10_000);
        }
        let mut ab = Metrics::new();
        ab.merge(&shard_a);
        ab.merge(&shard_b);
        let mut ba = Metrics::new();
        ba.merge(&shard_b);
        ba.merge(&shard_a);
        assert_eq!(ab.summary_row(), global.summary_row());
        assert_eq!(ba.summary_row(), global.summary_row());
        assert_eq!(ab.interval_met_rates(), global.interval_met_rates());
        assert_eq!(ba.interval_met_rates(), global.interval_met_rates());
        for id in 0..3u32 {
            let (g, a, b) = (
                global.dag(DagId(id)).unwrap(),
                ab.dag(DagId(id)).unwrap(),
                ba.dag(DagId(id)).unwrap(),
            );
            assert_eq!(a.completed, g.completed);
            assert_eq!(b.completed, g.completed);
            assert_eq!(a.e2e.tail_summary(), g.e2e.tail_summary());
            assert_eq!(b.qdelay.tail_summary(), g.qdelay.tail_summary());
        }
    }

    #[test]
    fn record_failure_reclassifies_a_timing_met_completion() {
        let mut m = Metrics::new();
        let ok = outcome(0, 0, 10 * MS, 20 * MS, 0); // met on timing
        let boom = outcome(0, 0, 15 * MS, 20 * MS, 1); // met on timing, will fail
        m.record_completion(&ok);
        m.record_completion(&boom);
        assert_eq!(m.total.deadlines_met, 2);
        m.record_failure(&boom);
        assert_eq!(m.total.completed, 2, "failed request stays completed");
        assert_eq!(m.total.failed, 1);
        assert_eq!(m.total.deadlines_met, 1, "failure revokes the met credit");
        assert!((m.total.deadline_met_rate() - 0.5).abs() < 1e-9);
        assert_eq!(m.dag(DagId(0)).unwrap().failed, 1);
        // interval credit taken back too
        assert_eq!(m.interval_met_rates(), vec![0.5]);
        // a timing-missed failure changes only the failed counter
        let late = outcome(0, 0, 50 * MS, 20 * MS, 0);
        m.record_completion(&late);
        m.record_failure(&late);
        assert_eq!(m.total.failed, 2);
        assert_eq!(m.total.deadlines_met, 1);
        // summary row carries the counter
        assert_eq!(m.summary_row().failed, 2);
        assert!(m.summary_row().format_line("x").contains("failed=2"));
    }

    #[test]
    fn merge_carries_failed_counts() {
        let mut a = Metrics::new();
        let boom = outcome(0, 0, 10 * MS, 20 * MS, 0);
        a.record_completion(&boom);
        a.record_failure(&boom);
        let mut b = Metrics::new();
        b.record_completion(&outcome(0, 0, 5 * MS, 20 * MS, 0));
        b.merge(&a);
        assert_eq!(b.total.completed, 2);
        assert_eq!(b.total.failed, 1);
        assert_eq!(b.total.deadlines_met, 1);
    }

    #[test]
    fn attainment_summary_matches_summary_row_and_pins_bucket_edges() {
        // Values 0..32 land in the histogram's exact unit buckets, so
        // percentiles are exact there: nearest-rank over 32 samples.
        let mut g = GroupStats::default();
        for v in 0..32u64 {
            g.e2e.record(v);
            g.completed += 1;
            g.deadlines_met += 1;
        }
        let att = g.attainment_summary();
        assert_eq!(att.p50, 15, "rank ceil(0.5*32)=16 → value 15");
        assert_eq!(att.p99, 31, "rank ceil(0.99*32)=32 → value 31");
        assert_eq!(att.p999, 31);
        assert_eq!(att.max, 31);
        assert_eq!(att.attainment, 1.0);
        assert_eq!(att.failed, 0);

        // Above the exact range, a quantile returns the containing
        // bucket's low edge clamped to the observed min/max: a single
        // large sample pins every percentile to itself.
        let mut one = GroupStats::default();
        one.e2e.record(1_000_003);
        one.completed = 1;
        let att1 = one.attainment_summary();
        assert_eq!(att1.p50, 1_000_003, "clamped to observed min");
        assert_eq!(att1.p999, 1_000_003);

        // Empty group: attainment defined as 1.0, percentiles 0.
        let empty = GroupStats::default().attainment_summary();
        assert_eq!(empty.attainment, 1.0);
        assert_eq!((empty.p50, empty.p99, empty.p999), (0, 0, 0));

        // The SummaryRow path must agree with the helper field-for-field.
        let mut m = Metrics::new();
        for i in 1..=100u64 {
            m.record_completion(&outcome(0, 0, i * MS, 200 * MS, 0));
        }
        let att = m.total.attainment_summary();
        let row = m.summary_row();
        assert_eq!(row.p50, att.p50);
        assert_eq!(row.p99, att.p99);
        assert_eq!(row.p999, att.p999);
        assert_eq!(row.deadline_met_rate, att.attainment);
        assert_eq!(row.completed, att.completed);
    }

    #[test]
    fn fmt_us_units() {
        assert_eq!(fmt_us(900), "900µs");
        assert_eq!(fmt_us(1_500), "1.50ms");
        assert_eq!(fmt_us(2_500_000), "2.50s");
    }

    #[test]
    fn csv_builder() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(&["1".into(), "2".into()]);
        assert_eq!(c.to_string(), "a,b\n1,2\n");
    }

    #[test]
    fn empty_metrics_sane() {
        let m = Metrics::new();
        let row = m.summary_row();
        assert_eq!(row.completed, 0);
        assert_eq!(row.deadline_met_rate, 1.0);
        assert!(m.interval_met_rates().is_empty());
    }
}
