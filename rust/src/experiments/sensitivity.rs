//! §7.5 sensitivity analyses: Fig 12 (scale-out threshold sweep) and
//! Fig 13 (SGS worker-pool sizing).

use crate::config::{Config, MS, SEC};
use crate::metrics::{fmt_us, Csv};
use crate::platform::{SimOptions, SimPlatform};
use crate::workload::ArrivalProcess;

use super::characterization::single_fn_app;
use super::{horizon, par_map, ExpContext, ExpResult};

/// Fig 12: SOT vs cold starts and tail E2E latency. Low SOT scales out
/// eagerly (more cold starts); high SOT tolerates queuing (worse tail).
/// The seven threshold legs are independent simulations and run on
/// scoped threads.
pub fn fig12(ctx: &ExpContext) -> ExpResult {
    let sots = [0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9];
    let legs = par_map(sots.to_vec(), |sot| {
        let mut cfg = Config::default();
        cfg.cluster.num_sgs = 5;
        cfg.cluster.workers_per_sgs = 8;
        cfg.cluster.cores_per_worker = 8;
        cfg.lbs.scale_out_threshold = sot;
        cfg.lbs.scale_in_threshold = (sot / 6.0).min(0.05);
        let app = single_fn_app(
            0,
            80 * MS,
            300 * MS,
            80 * MS + 120 * MS,
            ArrivalProcess::sinusoid(700.0, 500.0, 20 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 60),
            warmup: 5 * SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg, vec![app], opts);
        let row = p.run();
        (sot, p.total_cold_starts(), row, p.lbs().scale_outs())
    });
    let mut csv = Csv::new(&["sot", "cold_starts", "p999_us", "met_rate", "scale_outs"]);
    let mut rows = Vec::new();
    for (sot, colds, row, scale_outs) in legs {
        csv.row(&[
            format!("{sot}"),
            colds.to_string(),
            row.p999.to_string(),
            format!("{:.4}", row.deadline_met_rate),
            scale_outs.to_string(),
        ]);
        rows.push((sot, colds, row.p999, row.deadline_met_rate));
    }
    let path = ctx.path("fig12_sot_sweep.csv");
    csv.write(&path).unwrap();
    let lines: Vec<String> = rows
        .iter()
        .map(|(sot, colds, p999, met)| {
            format!(
                "  SOT={sot:<4} colds={colds:<6} p99.9={:<10} met={:.2}%",
                fmt_us(*p999),
                100.0 * met
            )
        })
        .collect();
    let summary = format!(
        "{}\npaper: low SOT → cold-start-driven tail; high SOT → queuing-driven\n\
         tail; 0.3 chosen as the operating point",
        lines.join("\n")
    );
    ExpResult {
        id: "fig12",
        title: "scale-out threshold sensitivity",
        summary,
        files: vec![path],
    }
}

/// Fig 13: cluster partitioning granularity — 20 workers split as
/// 20×1 / 10×2 / 5×4 / 1×20 under a sinusoidal single-DAG load.
pub fn fig13(ctx: &ExpContext) -> ExpResult {
    let partitions = [(20usize, 1usize), (10, 2), (5, 4), (1, 20)];
    let legs = par_map(partitions.to_vec(), |(num_sgs, workers)| {
        let mut cfg = Config::default();
        cfg.cluster.num_sgs = num_sgs;
        cfg.cluster.workers_per_sgs = workers;
        cfg.cluster.cores_per_worker = 8;
        let app = single_fn_app(
            0,
            80 * MS,
            300 * MS,
            80 * MS + 150 * MS,
            ArrivalProcess::sinusoid(600.0, 400.0, 20 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 60),
            warmup: 5 * SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg, vec![app], opts);
        let row = p.run();
        (num_sgs, workers, row, p.total_cold_starts(), p.lbs().scale_outs())
    });
    let mut csv = Csv::new(&["num_sgs", "workers_per_sgs", "p999_us", "met_rate", "cold_starts", "scale_outs"]);
    let mut rows = Vec::new();
    for (num_sgs, workers, row, colds, scale_outs) in legs {
        csv.row(&[
            num_sgs.to_string(),
            workers.to_string(),
            row.p999.to_string(),
            format!("{:.4}", row.deadline_met_rate),
            colds.to_string(),
            scale_outs.to_string(),
        ]);
        rows.push((num_sgs, workers, row.p999, colds, scale_outs));
    }
    let path = ctx.path("fig13_partitioning.csv");
    csv.write(&path).unwrap();
    let fine = rows.first().unwrap();
    let coarse = rows.last().unwrap();
    let lines: Vec<String> = rows
        .iter()
        .map(|(n, w, p999, colds, outs)| {
            format!(
                "  {n:>2} SGS x {w:>2} workers: p99.9={:<10} colds={colds:<6} scale-outs={outs}",
                fmt_us(*p999)
            )
        })
        .collect();
    let summary = format!(
        "{}\nfine-grained partitioning tail {:.1}x the coarse one (paper ~4x):\n\
         1-worker pools force constant scale-out, each adding cold starts",
        lines.join("\n"),
        fine.2 as f64 / coarse.2.max(1) as f64,
    );
    ExpResult {
        id: "fig13",
        title: "SGS worker-pool sizing (cluster partitioning)",
        summary,
        files: vec![path],
    }
}
