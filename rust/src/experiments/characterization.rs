//! §2.2 characterization (Fig 1, Fig 2) and Table 1.
//!
//! Fig 1/2 derive from the synthetic SAR population (see
//! `workload::sar` and DESIGN.md §4 for the substitution rationale);
//! Fig 2d runs the two §2.4 baselines head-to-head at ~70% utilization.

use crate::baseline::{BaselineKind, BaselineOptions, BaselineSim};
use crate::config::{MS, SEC};
use crate::dag::{DagId, DagSpec};
use crate::metrics::{fmt_us, Csv};
use crate::util::rng::Rng;
use crate::workload::{make_app, sar, App, ArrivalProcess, DagClass, WorkloadKind};

use super::{horizon, write_cdf, ExpContext, ExpResult};

/// Fig 1: exec-time / code-size / SNE / provisioned-memory distributions
/// of the top-50 SAR apps.
pub fn fig1(ctx: &ExpContext) -> ExpResult {
    let apps = sar::synthesize(50, ctx.seed);
    let stats = sar::stats(&apps);
    let mut csv = Csv::new(&[
        "app", "foreground", "exec_us", "setup_us", "sne", "code_kb", "prov_mb", "runtime_mb",
        "language",
    ]);
    for a in &apps {
        csv.row(&[
            a.name.clone(),
            a.foreground.to_string(),
            a.exec_time.to_string(),
            a.setup_time.to_string(),
            format!("{:.2}", a.sne()),
            a.code_size_kb.to_string(),
            a.provisioned_mb.to_string(),
            a.runtime_mb.to_string(),
            a.language.to_string(),
        ]);
    }
    let path = ctx.path("fig1_sar_population.csv");
    csv.write(&path).expect("write csv");
    let summary = format!(
        "T1 exec<100ms: {:.0}% (paper 57%) | exec>1s: {:.0}% (paper ~10%)\n\
         T2 max code: {:.1} MB (paper 34 MB)\n\
         T3 SNE>1: {:.0}% (paper 88%) | SNE>100x: {:.0}% (paper 37%)\n\
         T4 128MB provisioned: {:.0}% (paper 78%)",
        100.0 * stats.frac_exec_under_100ms,
        100.0 * stats.frac_exec_over_1s,
        stats.max_code_kb as f64 / 1024.0,
        100.0 * stats.frac_sne_over_1,
        100.0 * stats.frac_sne_over_100,
        100.0 * stats.frac_mem_128,
    );
    ExpResult {
        id: "fig1",
        title: "SAR app characterization (exec, code, SNE, memory)",
        summary,
        files: vec![path],
    }
}

/// Fig 2a–c: foreground/background splits + unused memory.
pub fn fig2abc(ctx: &ExpContext) -> ExpResult {
    let apps = sar::synthesize(50, ctx.seed);
    let stats = sar::stats(&apps);
    let mut csv = Csv::new(&["group", "metric", "value"]);
    let fg: Vec<_> = apps.iter().filter(|a| a.foreground).collect();
    let bg: Vec<_> = apps.iter().filter(|a| !a.foreground).collect();
    let med_sne = |set: &[&sar::SarApp]| {
        let mut v: Vec<f64> = set.iter().map(|a| a.sne()).collect();
        v.sort_by(|a, b| a.total_cmp(b));
        if v.is_empty() {
            0.0
        } else {
            v[v.len() / 2]
        }
    };
    csv.row(&["fg".into(), "frac_under_100ms".into(), format!("{:.3}", stats.frac_fg_under_100ms)]);
    csv.row(&["bg".into(), "frac_under_100ms".into(), format!("{:.3}", stats.frac_bg_under_100ms)]);
    csv.row(&["fg".into(), "median_sne".into(), format!("{:.1}", med_sne(&fg))]);
    csv.row(&["bg".into(), "median_sne".into(), format!("{:.1}", med_sne(&bg))]);
    csv.row(&["over128".into(), "mean_unused_frac".into(), format!("{:.3}", stats.mean_unused_mem_over_128)]);
    let path = ctx.path("fig2abc_fg_bg.csv");
    csv.write(&path).expect("write csv");
    let summary = format!(
        "fg exec<100ms: {:.0}% (paper ~65%) | bg exec<100ms: {:.0}% (paper <5%)\n\
         median SNE fg {:.0}x vs bg {:.0}x (paper: fg hit much harder)\n\
         unused memory for >128MB apps: {:.0}% (paper: significant fraction)",
        100.0 * stats.frac_fg_under_100ms,
        100.0 * stats.frac_bg_under_100ms,
        med_sne(&fg),
        med_sne(&bg),
        100.0 * stats.mean_unused_mem_over_128,
    );
    ExpResult {
        id: "fig2abc",
        title: "foreground/background splits + unused memory",
        summary,
        files: vec![path],
    }
}

/// Fig 2d: centralized FIFO vs Sparrow E2E latency at ~70% CPU.
pub fn fig2d(ctx: &ExpContext) -> ExpResult {
    // 20 workers × 8 cores = 160 cores; single-function DAGs at ~70%.
    let mut rng = Rng::new(ctx.seed);
    let mut apps: Vec<App> = Vec::new();
    for i in 0..6u32 {
        let mut a = make_app(DagClass::C1, DagId(i), WorkloadKind::W1, 1.0, &mut rng);
        // ~112 cores total: 6 dags × ~250 rps × 75 ms
        a.arrivals = ArrivalProcess::constant(250.0);
        apps.push(a);
    }
    let run = |kind| {
        let opts = BaselineOptions {
            kind,
            seed: ctx.seed,
            horizon: horizon(ctx, 40),
            warmup: 5 * SEC,
            decision_cost: 241,
            ..BaselineOptions::default()
        };
        let mut sim = BaselineSim::new(20, 8, 8 * 1024, apps.clone(), opts);
        let row = sim.run();
        (row, sim)
    };
    // Independent baseline stacks; run them on scoped threads.
    let mut legs = super::par_map(
        vec![BaselineKind::CentralizedFifo, BaselineKind::Sparrow { probes: 2 }],
        run,
    )
    .into_iter();
    let (fifo_row, fifo_sim) = legs.next().unwrap();
    let (sparrow_row, sparrow_sim) = legs.next().unwrap();
    let p_fifo = ctx.path("fig2d_fifo_cdf.csv");
    let p_spar = ctx.path("fig2d_sparrow_cdf.csv");
    write_cdf(&p_fifo, &fifo_sim.metrics.total.e2e).unwrap();
    write_cdf(&p_spar, &sparrow_sim.metrics.total.e2e).unwrap();
    let summary = format!(
        "FIFO:    p50={} p99={} p99.9={} (centralized decision queue + HoL blocking)\n\
         Sparrow: p50={} p99={} p99.9={} (scales, but probe placement misses warm sandboxes)\n\
         paper's point: both leave E2E latencies far above exec time under load",
        fmt_us(fifo_row.p50),
        fmt_us(fifo_row.p99),
        fmt_us(fifo_row.p999),
        fmt_us(sparrow_row.p50),
        fmt_us(sparrow_row.p99),
        fmt_us(sparrow_row.p999),
    );
    ExpResult {
        id: "fig2d",
        title: "FIFO vs Sparrow at ~70% cluster CPU",
        summary,
        files: vec![p_fifo, p_spar],
    }
}

/// Table 1: verify the generated classes sample within the table ranges.
pub fn table1(ctx: &ExpContext) -> ExpResult {
    let mut rng = Rng::new(ctx.seed);
    let mut csv = Csv::new(&["class", "exec_us", "slack_us", "deadline_us", "functions", "setup_us"]);
    let mut lines = Vec::new();
    for class in DagClass::ALL {
        let mut execs = Vec::new();
        let mut slacks = Vec::new();
        for i in 0..200u32 {
            let app = make_app(class, DagId(i), WorkloadKind::W2, 1.0, &mut rng);
            execs.push(app.dag.total_cpl);
            slacks.push(app.dag.slack());
            if i < 20 {
                csv.row(&[
                    class.name().into(),
                    app.dag.total_cpl.to_string(),
                    app.dag.slack().to_string(),
                    app.dag.deadline.to_string(),
                    app.dag.len().to_string(),
                    app.dag.functions[0].setup_time.to_string(),
                ]);
            }
        }
        let (e_lo, e_hi) = (execs.iter().min().unwrap(), execs.iter().max().unwrap());
        let (s_lo, s_hi) = (slacks.iter().min().unwrap(), slacks.iter().max().unwrap());
        lines.push(format!(
            "{}: exec {}..{} slack {}..{}",
            class.name(),
            fmt_us(*e_lo),
            fmt_us(*e_hi),
            fmt_us(*s_lo),
            fmt_us(*s_hi),
        ));
    }
    let path = ctx.path("table1_classes.csv");
    csv.write(&path).expect("write csv");
    ExpResult {
        id: "table1",
        title: "C1-C4 class parameters (Table 1 sampling check)",
        summary: lines.join("\n"),
        files: vec![path],
    }
}

/// Shared: a single-function DAG app with explicit arrivals.
pub(crate) fn single_fn_app(
    id: u32,
    exec: u64,
    setup: u64,
    deadline: u64,
    arrivals: ArrivalProcess,
) -> App {
    App {
        class: DagClass::C1,
        dag: DagSpec::single(DagId(id), &format!("dag{id}"), exec, setup, 128, deadline),
        arrivals,
    }
}

#[allow(unused_imports)]
use crate::config::Micros;
#[allow(dead_code)]
const _: Micros = MS;
