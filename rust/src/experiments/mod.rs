//! Experiment harnesses: one per table/figure of the paper's evaluation
//! (§2.2 characterization, §7 evaluation). Each harness regenerates its
//! figure's data as CSV under the output directory and returns a summary
//! with the headline comparison the paper reports. `archipelago figures
//! --all` runs everything; EXPERIMENTS.md records paper-vs-measured.

pub mod characterization;
pub mod macrobench;
pub mod placement;
pub mod scaling;
pub mod sensitivity;

use std::path::PathBuf;

/// Shared harness context.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub out_dir: PathBuf,
    /// Reduced horizons for bench/CI runs.
    pub quick: bool,
    pub seed: u64,
}

impl ExpContext {
    pub fn new(out_dir: &str) -> Self {
        ExpContext {
            out_dir: PathBuf::from(out_dir),
            quick: false,
            seed: 42,
        }
    }

    pub fn path(&self, file: &str) -> PathBuf {
        self.out_dir.join(file)
    }
}

/// One experiment's outcome: a human-readable summary block plus the
/// list of CSVs written.
#[derive(Debug)]
pub struct ExpResult {
    pub id: &'static str,
    pub title: &'static str,
    pub summary: String,
    pub files: Vec<PathBuf>,
}

impl ExpResult {
    pub fn render(&self) -> String {
        let mut s = format!("== {} — {} ==\n{}\n", self.id, self.title, self.summary);
        for f in &self.files {
            s.push_str(&format!("  wrote {}\n", f.display()));
        }
        s
    }
}

type ExpFn = fn(&ExpContext) -> ExpResult;

/// The experiment registry, in paper order.
pub fn registry() -> Vec<(&'static str, ExpFn)> {
    vec![
        ("fig1", characterization::fig1 as ExpFn),
        ("fig2abc", characterization::fig2abc as ExpFn),
        ("fig2d", characterization::fig2d as ExpFn),
        ("table1", characterization::table1 as ExpFn),
        ("fig7", macrobench::fig7 as ExpFn),
        ("fig8", macrobench::fig8 as ExpFn),
        ("fig9", placement::fig9 as ExpFn),
        ("lru", placement::lru_vs_fair as ExpFn),
        ("fig10", scaling::fig10 as ExpFn),
        ("fig11", scaling::fig11 as ExpFn),
        ("gradual", scaling::gradual_vs_instant as ExpFn),
        ("fig12", sensitivity::fig12 as ExpFn),
        ("fig13", sensitivity::fig13 as ExpFn),
    ]
}

/// Run one experiment by id.
pub fn run_one(id: &str, ctx: &ExpContext) -> Option<ExpResult> {
    registry()
        .into_iter()
        .find(|(name, _)| *name == id)
        .map(|(_, f)| f(ctx))
}

/// Run everything, returning results in paper order.
pub fn run_all(ctx: &ExpContext) -> Vec<ExpResult> {
    registry().into_iter().map(|(_, f)| f(ctx)).collect()
}

// ---------------------------------------------------------------------
// Shared helpers
// ---------------------------------------------------------------------

use crate::config::{Micros, SEC};
use crate::metrics::Csv;
use crate::util::stats::LogHistogram;

/// Write a latency CDF (percentile, value_us) for plotting.
pub(crate) fn write_cdf(path: &PathBuf, hist: &LogHistogram) -> std::io::Result<()> {
    let mut csv = Csv::new(&["percentile", "latency_us"]);
    for q in [
        0.01, 0.05, 0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99, 0.995, 0.999, 0.9999,
    ] {
        csv.row(&[format!("{q}"), hist.quantile(q).to_string()]);
    }
    csv.row(&["1.0".into(), hist.max().to_string()]);
    csv.write(path)
}

pub(crate) fn horizon(ctx: &ExpContext, full_secs: u64) -> Micros {
    if ctx.quick {
        (full_secs / 4).max(8) * SEC
    } else {
        full_secs * SEC
    }
}

/// Run independent experiment legs on scoped threads, preserving input
/// order. Sweeps over seeds × configs are separate simulations with no
/// shared state, so they parallelize trivially; a leg that panics
/// propagates the panic to the caller.
pub(crate) fn par_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .into_iter()
            .map(|item| s.spawn(move || f(item)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("experiment leg panicked"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = registry().iter().map(|(id, _)| *id).collect();
        for required in [
            "fig1", "fig2abc", "fig2d", "table1", "fig7", "fig8", "fig9", "lru",
            "fig10", "fig11", "gradual", "fig12", "fig13",
        ] {
            assert!(ids.contains(&required), "missing {required}");
        }
    }

    #[test]
    fn run_one_unknown_is_none() {
        let ctx = ExpContext::new("/tmp/archipelago_exp_test");
        assert!(run_one("nope", &ctx).is_none());
    }

    #[test]
    fn par_map_preserves_input_order() {
        let out = par_map((0..32).collect::<Vec<i64>>(), |i| i * i);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<i64>>());
    }
}
