//! §7.3.2 LBS scaling-strategy microbenchmarks: Fig 10 (deadline-aware
//! per-DAG scale-out), Fig 11 (contention-aware scale-out), and the
//! gradual-vs-instant scale-out comparison. All use the §7.3 setup:
//! 5 SGSs × 10 workers.

use crate::config::{Config, ScaleOutMode, MS, SEC};
use crate::metrics::{fmt_us, Csv};
use crate::platform::{SimOptions, SimPlatform};
use crate::workload::ArrivalProcess;

use super::characterization::single_fn_app;
use super::{horizon, par_map, ExpContext, ExpResult};

fn micro_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 5;
    cfg.cluster.workers_per_sgs = 10;
    cfg.cluster.cores_per_worker = 8;
    cfg.cluster.proactive_pool_mb = 16 * 1024;
    cfg
}

fn sgs_series_csv(p: &SimPlatform, dags: &[u32]) -> Csv {
    let mut header = vec!["time_s".to_string()];
    header.extend(dags.iter().map(|d| format!("dag{d}_sgs")));
    let mut csv = Csv::new(&header.iter().map(|s| s.as_str()).collect::<Vec<_>>());
    let series: Vec<&Vec<(u64, f64)>> = dags
        .iter()
        .map(|d| &p.series[&format!("active_sgs.dag{d}")])
        .collect();
    let len = series.iter().map(|s| s.len()).min().unwrap_or(0);
    for i in (0..len).step_by(5) {
        let t = series[0][i].0;
        let mut row = vec![format!("{:.1}", t as f64 / SEC as f64)];
        row.extend(series.iter().map(|s| format!("{:.0}", s[i].1)));
        csv.row(&row);
    }
    csv
}

/// Fig 10: identical load, different slack — the low-slack DAG scales
/// out to more SGSs (deadline-aware scaling metric). Each DAG runs
/// against its own copy of the cluster with the identical arrival
/// stream, isolating the slack normalization in the scaling metric
/// (co-locating them would let SRSF's prioritization of the tight DAG
/// mask the effect — see EXPERIMENTS.md).
pub fn fig10(ctx: &ExpContext) -> ExpResult {
    let run = |slack_ms: u64| {
        let app = single_fn_app(
            0,
            100 * MS,
            250 * MS,
            100 * MS + slack_ms * MS,
            ArrivalProcess::sinusoid(700.0, 500.0, 20 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 60),
            warmup: 5 * SEC,
            record_series: true,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(micro_cfg(), vec![app], opts);
        p.run();
        let series = p.series["active_sgs.dag0"].clone();
        let max = series.iter().map(|(_, v)| *v as u32).max().unwrap_or(1);
        let mean = series.iter().map(|(_, v)| v).sum::<f64>() / series.len() as f64;
        (series, max, mean)
    };
    // The two slack legs are independent simulations; run them on
    // scoped threads.
    let mut legs = par_map(vec![50u64, 200], run).into_iter();
    let (tight_series, tight_max, tight_mean) = legs.next().unwrap();
    let (loose_series, loose_max, loose_mean) = legs.next().unwrap();
    let mut csv = Csv::new(&["time_s", "slack50_sgs", "slack200_sgs"]);
    for i in (0..tight_series.len().min(loose_series.len())).step_by(5) {
        csv.row(&[
            format!("{:.1}", tight_series[i].0 as f64 / SEC as f64),
            format!("{:.0}", tight_series[i].1),
            format!("{:.0}", loose_series[i].1),
        ]);
    }
    let path = ctx.path("fig10_slack_scaleout.csv");
    csv.write(&path).unwrap();
    let summary = format!(
        "slack 50ms:  max {tight_max} SGSs, mean {tight_mean:.2}\n\
         slack 200ms: max {loose_max} SGSs, mean {loose_mean:.2}\n\
         the tighter-slack DAG scales out further under identical load\n\
         (paper: 4 vs 3 SGSs in the 20-30s interval)",
    );
    ExpResult {
        id: "fig10",
        title: "deadline-aware per-DAG scale-out (slack 50ms vs 200ms)",
        summary,
        files: vec![path],
    }
}

/// Fig 11: a bursty DAG creates contention; the constant-rate DAG
/// sharing its SGS scales out, then back in when contention passes.
pub fn fig11(ctx: &ExpContext) -> ExpResult {
    let bursty = single_fn_app(
        0,
        100 * MS,
        250 * MS,
        250 * MS,
        ArrivalProcess::sinusoid(600.0, 550.0, 30 * SEC),
    );
    // low constant rate: needs only one SGS when alone
    let steady = single_fn_app(
        1,
        100 * MS,
        250 * MS,
        250 * MS,
        ArrivalProcess::constant(150.0),
    );
    let opts = SimOptions {
        seed: ctx.seed,
        horizon: horizon(ctx, 90),
        warmup: 5 * SEC,
        record_series: true,
        ..SimOptions::default()
    };
    // 2 SGSs so the bursty DAG necessarily contends with the steady one.
    let mut cfg = micro_cfg();
    cfg.cluster.num_sgs = 3;
    let mut p = SimPlatform::new(cfg, vec![bursty, steady], opts);
    let row = p.run();
    let steady_series = &p.series["active_sgs.dag1"];
    let max_steady = steady_series.iter().map(|(_, v)| *v as u32).max().unwrap();
    let min_steady_late = steady_series
        .iter()
        .filter(|(t, _)| *t > steady_series.last().unwrap().0 / 2)
        .map(|(_, v)| *v as u32)
        .min()
        .unwrap();
    let csv = sgs_series_csv(&p, &[0, 1]);
    let path = ctx.path("fig11_contention_scaleout.csv");
    csv.write(&path).unwrap();
    let summary = format!(
        "steady DAG (150 rps, fits one SGS alone): scaled out to {} SGSs under\n\
         contention from the bursty DAG, back down to {} later\n\
         (paper: scale-out at ~5s of contention, scale-in at ~17s)\n\
         overall met rate {:.2}%",
        max_steady,
        min_steady_late,
        100.0 * row.deadline_met_rate,
    );
    ExpResult {
        id: "fig11",
        title: "contention-aware per-DAG scale-out",
        summary,
        files: vec![path],
    }
}

/// §7.3.2 gradual vs instant scale-out (paper: instant is 1.5x worse on
/// tail latency).
pub fn gradual_vs_instant(ctx: &ExpContext) -> ExpResult {
    let run = |mode: ScaleOutMode| {
        let mut cfg = micro_cfg();
        cfg.lbs.scale_out_mode = mode;
        // paper: avg 800 RPS, amplitude 600, elongated 100 s period
        let app = single_fn_app(
            0,
            100 * MS,
            300 * MS,
            100 * MS + 150 * MS,
            ArrivalProcess::sinusoid(800.0, 600.0, 100 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 120),
            warmup: 5 * SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg, vec![app], opts);
        let row = p.run();
        let colds = p.total_cold_starts();
        (row, colds)
    };
    let mut legs = par_map(vec![ScaleOutMode::Gradual, ScaleOutMode::Instant], run).into_iter();
    let (grad_row, grad_colds) = legs.next().unwrap();
    let (inst_row, inst_colds) = legs.next().unwrap();
    let mut csv = Csv::new(&["mode", "p50_us", "p99_us", "p999_us", "met_rate", "cold_starts"]);
    for (name, row, colds) in [
        ("gradual", &grad_row, grad_colds),
        ("instant", &inst_row, inst_colds),
    ] {
        csv.row(&[
            name.into(),
            row.p50.to_string(),
            row.p99.to_string(),
            row.p999.to_string(),
            format!("{:.4}", row.deadline_met_rate),
            colds.to_string(),
        ]);
    }
    let path = ctx.path("gradual_vs_instant.csv");
    csv.write(&path).unwrap();
    let ratio = inst_row.p999 as f64 / grad_row.p999.max(1) as f64;
    let summary = format!(
        "gradual: p99.9={} met={:.2}% colds={grad_colds}\n\
         instant: p99.9={} met={:.2}% colds={inst_colds}\n\
         instant scale-out tail {ratio:.2}x worse (paper 1.5x): round-robin to the\n\
         new SGS before it has sandboxes forces setup onto the critical path",
        fmt_us(grad_row.p999),
        100.0 * grad_row.deadline_met_rate,
        fmt_us(inst_row.p999),
        100.0 * inst_row.deadline_met_rate,
    );
    ExpResult {
        id: "gradual",
        title: "gradual vs instant scale-out",
        summary,
        files: vec![path],
    }
}
