//! §7.2 macrobenchmarks: Fig 7 (Archipelago vs baseline, Workloads 1–2)
//! and Fig 8 (sources of improvement for Workload 2).
//!
//! Configuration mirrors §7.1: the 8 SGS × 8 worker × 20-core testbed,
//! C1–C4 DAG classes (two per class), sandbox setups 125–400 ms,
//! SOT = 0.3. Rates are scaled so peak offered load reaches ~100% of
//! cluster CPU (the paper kept its cluster between ~70% and ~110%).
//! The baseline gets the same hardware with an 8 GB/worker container
//! pool (OpenWhisk invoker-style userMemory) and a 100 µs serialized
//! decision cost; see EXPERIMENTS.md for the paper-vs-measured notes.

use crate::baseline::{BaselineKind, BaselineOptions, BaselineSim};
use crate::config::{Config, SEC};
use crate::metrics::{fmt_us, Csv, SummaryRow};
use crate::platform::{SimOptions, SimPlatform};
use crate::workload::{macro_mix, peak_offered_cores, App, DagClass, WorkloadKind};

use super::{horizon, write_cdf, ExpContext, ExpResult};

pub(crate) const BASELINE_POOL_MB: u64 = 8 * 1024;
pub(crate) const BASELINE_DECISION_US: u64 = 100;

/// Build the §7.2 workload: 2 DAGs/class, peak-scaled to the cluster.
pub(crate) fn paper_mix(kind: WorkloadKind, cfg: &Config, seed: u64) -> Vec<App> {
    let total = cfg.total_cores() as f64;
    let probe = macro_mix(kind, 2, 1.0, seed);
    let peak: f64 = probe.iter().map(peak_offered_cores).sum();
    macro_mix(kind, 2, total / peak, seed)
}

pub(crate) struct MacroRun {
    pub arch: SummaryRow,
    pub base: SummaryRow,
    pub arch_platform: SimPlatform,
    pub base_sim: BaselineSim,
}

pub(crate) fn run_macro(ctx: &ExpContext, kind: WorkloadKind, record_series: bool) -> MacroRun {
    let cfg = Config::default();
    let apps = paper_mix(kind, &cfg, ctx.seed);
    let hz = horizon(ctx, 120);
    let warmup = hz / 4;
    let opts = SimOptions {
        seed: ctx.seed,
        horizon: hz,
        warmup,
        record_series,
        ..SimOptions::default()
    };
    let bopts = BaselineOptions {
        kind: BaselineKind::CentralizedFifo,
        seed: ctx.seed,
        horizon: hz,
        warmup,
        decision_cost: BASELINE_DECISION_US,
        ..BaselineOptions::default()
    };
    // The Archipelago and baseline runs share nothing; overlap them.
    let (arch_leg, base_leg) = std::thread::scope(|s| {
        let arch_apps = apps.clone();
        let arch_cfg = cfg.clone();
        let arch_h = s.spawn(move || {
            let mut p = SimPlatform::new(arch_cfg, arch_apps, opts);
            let row = p.run();
            (row, p)
        });
        let base_h = s.spawn(move || {
            let mut sim = BaselineSim::new(
                cfg.cluster.num_sgs * cfg.cluster.workers_per_sgs,
                cfg.cluster.cores_per_worker,
                BASELINE_POOL_MB,
                apps,
                bopts,
            );
            let row = sim.run();
            (row, sim)
        });
        (
            arch_h.join().expect("archipelago run panicked"),
            base_h.join().expect("baseline run panicked"),
        )
    });
    let (arch, arch_platform) = arch_leg;
    let (base, base_sim) = base_leg;
    MacroRun {
        arch,
        base,
        arch_platform,
        base_sim,
    }
}

fn class_rows(platform: &SimPlatform) -> String {
    let mut lines = Vec::new();
    for (ci, class) in DagClass::ALL.iter().enumerate() {
        let (mut met, mut n, mut cold) = (0u64, 0u64, 0u64);
        for id in [2 * ci as u32, 2 * ci as u32 + 1] {
            if let Some(g) = platform.metrics().per_dag.get(&id) {
                met += g.deadlines_met;
                n += g.completed;
                cold += g.cold_starts;
            }
        }
        lines.push(format!(
            "  {}: met={:6.2}% n={n} cold={cold}",
            class.name(),
            100.0 * met as f64 / n.max(1) as f64
        ));
    }
    lines.join("\n")
}

/// Fig 7: E2E latency CDFs + % deadlines met, both workloads (run on
/// scoped threads — they are independent simulations).
pub fn fig7(ctx: &ExpContext) -> ExpResult {
    let mut files = Vec::new();
    let mut blocks = Vec::new();
    let workloads = vec![
        (WorkloadKind::W1, "w1", "20.83x", "0.76% vs 33%"),
        (WorkloadKind::W2, "w2", "35.97x", "0.98% vs 9.66%"),
    ];
    let legs = super::par_map(workloads, |(kind, label, paper_tail, paper_missed)| {
        (run_macro(ctx, kind, false), label, paper_tail, paper_missed)
    });
    for (run, label, paper_tail, paper_missed) in legs {
        let pa = ctx.path(&format!("fig7_{label}_archipelago_cdf.csv"));
        let pb = ctx.path(&format!("fig7_{label}_baseline_cdf.csv"));
        write_cdf(&pa, &run.arch_platform.metrics().total.e2e).unwrap();
        write_cdf(&pb, &run.base_sim.metrics.total.e2e).unwrap();
        let mut met_csv = Csv::new(&["system", "class", "deadline_met_rate"]);
        for (ci, class) in DagClass::ALL.iter().enumerate() {
            for (sys, m) in [
                ("archipelago", run.arch_platform.metrics()),
                ("baseline", &run.base_sim.metrics),
            ] {
                let (mut met, mut n) = (0u64, 0u64);
                for id in [2 * ci as u32, 2 * ci as u32 + 1] {
                    if let Some(g) = m.per_dag.get(&id) {
                        met += g.deadlines_met;
                        n += g.completed;
                    }
                }
                met_csv.row(&[
                    sys.into(),
                    class.name().into(),
                    format!("{:.4}", met as f64 / n.max(1) as f64),
                ]);
            }
        }
        let pm = ctx.path(&format!("fig7_{label}_deadlines_met.csv"));
        met_csv.write(&pm).unwrap();
        let tail_ratio = run.base.p999 as f64 / run.arch.p999.max(1) as f64;
        blocks.push(format!(
            "{}:\n{}\n{}\n  tail p99.9 ratio base/arch = {tail_ratio:.1}x (paper {paper_tail})\n\
             \x20 missed: arch {:.2}% vs base {:.2}% (paper {paper_missed})\n\
             \x20 per-class (archipelago):\n{}",
            label.to_uppercase(),
            run.arch.format_line("  archipelago"),
            run.base.format_line("  baseline"),
            100.0 * (1.0 - run.arch.deadline_met_rate),
            100.0 * (1.0 - run.base.deadline_met_rate),
            class_rows(&run.arch_platform),
        ));
        files.extend([pa, pb, pm]);
    }
    ExpResult {
        id: "fig7",
        title: "macrobenchmark: Archipelago vs baseline (W1 + W2)",
        summary: blocks.join("\n"),
        files,
    }
}

/// Fig 8: sources of improvement on Workload 2 — queuing-delay CDFs and
/// proactive-vs-ideal sandbox allocation for a C2 DAG.
pub fn fig8(ctx: &ExpContext) -> ExpResult {
    let run = run_macro(ctx, WorkloadKind::W2, true);
    // (a) queuing delay
    let pa = ctx.path("fig8a_arch_qdelay_cdf.csv");
    let pb = ctx.path("fig8a_base_qdelay_cdf.csv");
    write_cdf(&pa, &run.arch_platform.metrics().total.qdelay).unwrap();
    write_cdf(&pb, &run.base_sim.metrics.total.qdelay).unwrap();
    let q_ratio =
        run.base.qdelay_p999 as f64 / run.arch.qdelay_p999.max(1) as f64;
    let cold_ratio = run.base.cold_starts as f64 / run.arch.cold_starts.max(1) as f64;

    // (b) proactive allocation vs ideal for the first C2 DAG (id 2):
    // sum per-SGS series.
    let mut alloc: std::collections::BTreeMap<u64, f64> = Default::default();
    let mut busy: std::collections::BTreeMap<u64, f64> = Default::default();
    for (name, series) in &run.arch_platform.series {
        let target = if name.starts_with("sandboxes.dag2.") {
            Some(&mut alloc)
        } else if name.starts_with("busy.dag2.") {
            Some(&mut busy)
        } else {
            None
        };
        if let Some(map) = target {
            for (t, v) in series {
                *map.entry(*t / (SEC / 2)).or_insert(0.0) += v;
            }
        }
    }
    let mut csv = Csv::new(&["time_s", "allocated", "ideal_busy"]);
    #[allow(unused_mut)]
    let mut overprov: Vec<f64> = Vec::new();
    for (t, a) in &alloc {
        let b = busy.get(t).copied().unwrap_or(0.0);
        // series sampled 5x per half-second bucket per SGS: normalize
        let a = a / 5.0;
        let b = b / 5.0;
        csv.row(&[format!("{:.1}", *t as f64 / 2.0), format!("{a:.1}"), format!("{b:.1}")]);
        // over-allocation is meaningful only when the DAG is actually
        // busy (the troughs of the sinusoid divide by ~zero)
        if b > 10.0 {
            overprov.push((a - b) / b);
        }
    }
    let pc = ctx.path("fig8b_proactive_vs_ideal.csv");
    csv.write(&pc).unwrap();
    overprov.sort_by(|a, b| a.total_cmp(b));
    let med_over = overprov.get(overprov.len() / 2).copied().unwrap_or(0.0);
    let p90_over = overprov
        .get((overprov.len() as f64 * 0.9) as usize)
        .copied()
        .unwrap_or(0.0);

    let summary = format!(
        "qdelay p99.9: arch {} vs base {} — {q_ratio:.1}x lower (paper 47.5x)\n\
         cold starts: arch {} vs base {} — {cold_ratio:.1}x fewer (paper 24.38x)\n\
         C2 allocation tracks demand: median over-allocation {:.0}%, p90 {:.0}%\n\
         (paper: worst case 37.4% over ideal; ours provisions for the 99th\n\
         percentile of arrivals plus margin, so bursts are covered)",
        fmt_us(run.arch.qdelay_p999),
        fmt_us(run.base.qdelay_p999),
        run.arch.cold_starts,
        run.base.cold_starts,
        100.0 * med_over,
        100.0 * p90_over,
    );
    ExpResult {
        id: "fig8",
        title: "W2 sources of improvement: queuing delay + proactive allocation",
        summary,
        files: vec![pa, pb, pc],
    }
}
