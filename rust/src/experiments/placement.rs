//! §7.3.1 SGS sandbox-management microbenchmarks: Fig 9 (even vs packed
//! placement) and the fair-vs-LRU hard-eviction comparison.

use crate::config::{Config, EvictionPolicy, PlacementPolicy, MS, SEC};
use crate::metrics::{fmt_us, Csv};
use crate::platform::{SimOptions, SimPlatform};
use crate::workload::ArrivalProcess;

use super::characterization::single_fn_app;
use super::{horizon, par_map, ExpContext, ExpResult};

fn micro_cfg(num_sgs: usize) -> Config {
    // §7.3: one LB, N SGSs with 10 workers each.
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = num_sgs;
    cfg.cluster.workers_per_sgs = 10;
    cfg.cluster.cores_per_worker = 16;
    cfg.cluster.proactive_pool_mb = 16 * 1024;
    cfg
}

/// Fig 9: even vs packed placement under a sinusoidal single-DAG load
/// (avg 1200 RPS, amplitude 600, period 20 s, 1 SGS × 10 workers).
pub fn fig9(ctx: &ExpContext) -> ExpResult {
    let run = |placement: PlacementPolicy| {
        let mut cfg = micro_cfg(1);
        cfg.sgs.placement = placement;
        let app = single_fn_app(
            0,
            75 * MS,
            250 * MS,
            75 * MS + 150 * MS,
            ArrivalProcess::sinusoid(1200.0, 600.0, 20 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 80),
            warmup: 0, // Fig 9 plots per-interval series from t=0
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg, vec![app], opts);
        let row = p.run();
        (row, p.metrics().interval_met_rates())
    };
    let mut legs = par_map(vec![PlacementPolicy::Even, PlacementPolicy::Packed], run).into_iter();
    let (even_row, even_series) = legs.next().unwrap();
    let (packed_row, packed_series) = legs.next().unwrap();
    let mut csv = Csv::new(&["interval_s", "even_met_rate", "packed_met_rate"]);
    for (i, (e, p)) in even_series.iter().zip(&packed_series).enumerate() {
        csv.row(&[i.to_string(), format!("{e:.4}"), format!("{p:.4}")]);
    }
    let path = ctx.path("fig9_even_vs_packed.csv");
    csv.write(&path).unwrap();
    let worst_packed = packed_series
        .iter()
        .skip(2)
        .cloned()
        .fold(1.0, f64::min);
    let worst_even = even_series.iter().skip(2).cloned().fold(1.0, f64::min);
    let summary = format!(
        "even:   met={:.2}% (worst interval {:.0}%)\n\
         packed: met={:.2}% (worst interval {:.0}% — paper: ~30% at load peaks)\n\
         packing concentrates sandboxes; at peaks requests land on workers\n\
         without warm sandboxes and pay the setup cost",
        100.0 * even_row.deadline_met_rate,
        100.0 * worst_even,
        100.0 * packed_row.deadline_met_rate,
        100.0 * worst_packed,
    );
    ExpResult {
        id: "fig9",
        title: "sandbox placement: even vs packed",
        summary,
        files: vec![path],
    }
}

/// §7.3.1 "Benefits of workload-aware hard eviction": fair vs LRU under
/// pool pressure with a constant DAG + an on/off DAG.
pub fn lru_vs_fair(ctx: &ExpContext) -> ExpResult {
    let run = |eviction: EvictionPolicy| {
        let mut cfg = micro_cfg(1);
        cfg.sgs.eviction = eviction;
        // Small pool so the two DAGs contend for sandbox memory, and a
        // slow rate EWMA so the on/off DAG's demand estimate persists
        // through its off period — the fair policy then protects its
        // sandboxes while LRU recycles them by idleness.
        cfg.cluster.proactive_pool_mb = 1024;
        cfg.cluster.workers_per_sgs = 4;
        cfg.cluster.cores_per_worker = 16;
        cfg.sgs.rate_ewma_alpha = 0.02;
        let steady = single_fn_app(
            0,
            60 * MS,
            300 * MS,
            60 * MS + 200 * MS,
            ArrivalProcess::sinusoid(150.0, 100.0, 10 * SEC),
        );
        let onoff = single_fn_app(
            1,
            60 * MS,
            300 * MS,
            60 * MS + 200 * MS,
            ArrivalProcess::on_off(100.0, 3 * SEC, 7 * SEC),
        );
        let opts = SimOptions {
            seed: ctx.seed,
            horizon: horizon(ctx, 80),
            warmup: 10 * SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg, vec![steady, onoff], opts);
        let row = p.run();
        let colds = p.total_cold_starts();
        (row, colds)
    };
    let mut legs = par_map(vec![EvictionPolicy::Fair, EvictionPolicy::Lru], run).into_iter();
    let (fair_row, fair_colds) = legs.next().unwrap();
    let (lru_row, lru_colds) = legs.next().unwrap();
    let mut csv = Csv::new(&["policy", "p50_us", "p99_us", "p999_us", "met_rate", "cold_starts"]);
    for (name, row, colds) in [
        ("fair", &fair_row, fair_colds),
        ("lru", &lru_row, lru_colds),
    ] {
        csv.row(&[
            name.into(),
            row.p50.to_string(),
            row.p99.to_string(),
            row.p999.to_string(),
            format!("{:.4}", row.deadline_met_rate),
            colds.to_string(),
        ]);
    }
    let path = ctx.path("lru_vs_fair.csv");
    csv.write(&path).unwrap();
    let ratio = lru_row.p999 as f64 / fair_row.p999.max(1) as f64;
    let summary = format!(
        "fair: p99.9={} met={:.2}% colds={fair_colds}\n\
         lru:  p99.9={} met={:.2}% colds={lru_colds}\n\
         LRU tail {ratio:.2}x worse (paper 4.62x): during the off period LRU\n\
         hard-evicts the idle DAG's sandboxes; every on-period restart pays setup",
        fmt_us(fair_row.p999),
        100.0 * fair_row.deadline_met_rate,
        fmt_us(lru_row.p999),
        100.0 * lru_row.deadline_met_rate,
    );
    ExpResult {
        id: "lru",
        title: "hard eviction: fair (demand-aware) vs LRU",
        summary,
        files: vec![path],
    }
}
