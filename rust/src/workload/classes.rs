//! The four DAG classes of §7.1 / Table 1.
//!
//! | class | shape            | exec (ms) | slack (ms) | W2 RPS / amp / period |
//! |-------|------------------|-----------|------------|-----------------------|
//! | C1    | single fn        | 50–100    | 100–150    | 600–1200 / 100–800 / 10–20 s |
//! | C2    | single fn        | 100–200   | 300–500    | 400–800 / 200–400 / 30–40 s |
//! | C3    | chain            | 250–400   | 200–300    | 500–1000 / 200–600 / 10–20 s |
//! | C4    | branched         | 300–600   | 500–1000   | 200 / 0 / ∞ |
//!
//! Workload 1 replaces the sinusoids with per-second resampled Poisson
//! rates (C1 800–1200, C2 600–900, C3 600–800, C4 50–150 RPS). Sandbox
//! setup overheads are sampled per DAG from 125–400 ms (§7.1); memory is
//! 128 MB per function (T4).

use crate::config::{Micros, MS, SEC};
use crate::dag::{DagId, DagSpec, FunctionSpec};
use crate::util::rng::Rng;

use super::arrival::ArrivalProcess;

/// The four workload classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DagClass {
    C1,
    C2,
    C3,
    C4,
}

impl DagClass {
    pub const ALL: [DagClass; 4] = [DagClass::C1, DagClass::C2, DagClass::C3, DagClass::C4];

    pub fn name(self) -> &'static str {
        match self {
            DagClass::C1 => "C1",
            DagClass::C2 => "C2",
            DagClass::C3 => "C3",
            DagClass::C4 => "C4",
        }
    }

    /// Foreground (user-facing, tight deadline) vs background.
    pub fn is_foreground(self) -> bool {
        !matches!(self, DagClass::C4)
    }
}

/// Which arrival model drives the run (§7.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Poisson with per-second resampled mean rate.
    W1,
    /// Sinusoidal rate modulation.
    W2,
}

/// One generated application: a DAG plus its arrival process.
#[derive(Debug, Clone)]
pub struct App {
    pub class: DagClass,
    pub dag: DagSpec,
    pub arrivals: ArrivalProcess,
}

/// Per-function memory footprint (T4: 78% of SAR functions fit 128 MB).
pub const FN_MEM_MB: u64 = 128;

fn sample_range_us(rng: &mut Rng, lo_ms: u64, hi_ms: u64) -> Micros {
    rng.range_u64(lo_ms * MS, hi_ms * MS + 1)
}

/// Sample the per-DAG sandbox setup overhead (125–400 ms, §7.1).
pub fn sample_setup(rng: &mut Rng) -> Micros {
    rng.range_u64(125 * MS, 400 * MS + 1)
}

/// Build one app of `class` (Table 1 sampling). `rate_scale` scales the
/// arrival rate so multi-DAG runs can hit a target cluster utilization.
pub fn make_app(
    class: DagClass,
    id: DagId,
    kind: WorkloadKind,
    rate_scale: f64,
    rng: &mut Rng,
) -> App {
    let setup = sample_setup(rng);
    let (dag, exec_total) = match class {
        DagClass::C1 => {
            let exec = sample_range_us(rng, 50, 100);
            let slack = sample_range_us(rng, 100, 150);
            (
                DagSpec::single(id, &format!("c1-{}", id.0), exec, setup, FN_MEM_MB, exec + slack),
                exec,
            )
        }
        DagClass::C2 => {
            let exec = sample_range_us(rng, 100, 200);
            let slack = sample_range_us(rng, 300, 500);
            (
                DagSpec::single(id, &format!("c2-{}", id.0), exec, setup, FN_MEM_MB, exec + slack),
                exec,
            )
        }
        DagClass::C3 => {
            // chained functions with 250–400 ms total execution
            let exec_total = sample_range_us(rng, 250, 400);
            let slack = sample_range_us(rng, 200, 300);
            let stages = rng.range_usize(2, 4); // 2–3 functions
            let per = exec_total / stages as u64;
            let spec: Vec<(Micros, Micros, u64)> =
                (0..stages).map(|_| (per, setup, FN_MEM_MB)).collect();
            (
                DagSpec::chain(id, &format!("c3-{}", id.0), &spec, per * stages as u64 + slack),
                per * stages as u64,
            )
        }
        DagClass::C4 => {
            // branched structure: fan-out then join (batch jobs, §7.1)
            let exec_total = sample_range_us(rng, 300, 600);
            let slack = sample_range_us(rng, 500, 1000);
            let branches = rng.range_usize(2, 4);
            // root third, branches third (parallel), join third.
            // Function names carry the DAG prefix: the real-time
            // executors key warm state by *name*, so two C4 apps must
            // not alias each other's sandboxes.
            let part = exec_total / 3;
            let prefix = format!("c4-{}", id.0);
            let mut functions = vec![FunctionSpec::new(
                &format!("{prefix}-root"),
                part,
                setup,
                FN_MEM_MB,
            )];
            let mut edges = Vec::new();
            for b in 0..branches {
                functions.push(FunctionSpec::new(
                    &format!("{prefix}-branch{b}"),
                    part,
                    setup,
                    FN_MEM_MB,
                ));
                edges.push((0u16, (b + 1) as u16));
            }
            let join_idx = (branches + 1) as u16;
            functions.push(FunctionSpec::new(
                &format!("{prefix}-join"),
                part,
                setup,
                FN_MEM_MB,
            ));
            for b in 0..branches {
                edges.push(((b + 1) as u16, join_idx));
            }
            let cpl = 3 * part; // root + one branch + join
            let dag = DagSpec::new(
                id,
                &format!("c4-{}", id.0),
                functions,
                edges,
                cpl + slack,
            )
            .expect("generated branched dag is valid");
            (dag, cpl)
        }
    };
    debug_assert_eq!(dag.total_cpl, exec_total);

    let arrivals = match (kind, class) {
        (WorkloadKind::W1, DagClass::C1) => scaled_resample(rng, 800.0, 1200.0, rate_scale),
        (WorkloadKind::W1, DagClass::C2) => scaled_resample(rng, 600.0, 900.0, rate_scale),
        (WorkloadKind::W1, DagClass::C3) => scaled_resample(rng, 600.0, 800.0, rate_scale),
        (WorkloadKind::W1, DagClass::C4) => scaled_resample(rng, 50.0, 150.0, rate_scale),
        (WorkloadKind::W2, DagClass::C1) => sin_from_table(rng, 600.0, 1200.0, 100.0, 800.0, 10, 20, rate_scale),
        (WorkloadKind::W2, DagClass::C2) => sin_from_table(rng, 400.0, 800.0, 200.0, 400.0, 30, 40, rate_scale),
        (WorkloadKind::W2, DagClass::C3) => sin_from_table(rng, 500.0, 1000.0, 200.0, 600.0, 10, 20, rate_scale),
        (WorkloadKind::W2, DagClass::C4) => {
            ArrivalProcess::constant((200.0 * rate_scale).max(0.1))
        }
    };
    App {
        class,
        dag,
        arrivals,
    }
}

fn scaled_resample(rng: &mut Rng, lo: f64, hi: f64, scale: f64) -> ArrivalProcess {
    let _ = rng;
    ArrivalProcess::resampled((lo * scale).max(0.1), (hi * scale).max(0.2), SEC)
}

fn sin_from_table(
    rng: &mut Rng,
    avg_lo: f64,
    avg_hi: f64,
    amp_lo: f64,
    amp_hi: f64,
    period_lo_s: u64,
    period_hi_s: u64,
    scale: f64,
) -> ArrivalProcess {
    let avg = rng.range_f64(avg_lo, avg_hi) * scale;
    let amp = (rng.range_f64(amp_lo, amp_hi) * scale).min(avg); // amp ≤ avg
    let period = rng.range_u64(period_lo_s * SEC, period_hi_s * SEC + 1);
    ArrivalProcess::sinusoid(avg.max(0.1), amp, period)
}

/// The §7.2 macrobenchmark mix: `dags_per_class` apps of each class.
pub fn macro_mix(
    kind: WorkloadKind,
    dags_per_class: usize,
    rate_scale: f64,
    seed: u64,
) -> Vec<App> {
    let mut rng = Rng::new(seed);
    let mut apps = Vec::new();
    let mut next_id = 0u32;
    for class in DagClass::ALL {
        for _ in 0..dags_per_class {
            let mut stream = rng.fork(next_id as u64);
            apps.push(make_app(class, DagId(next_id), kind, rate_scale, &mut stream));
            next_id += 1;
        }
    }
    apps
}

/// Peak offered CPU load of an app in cores (max rate × total exec).
/// Used to scale multi-DAG mixes so the cluster stays in the paper's
/// ~70–110% CPU band (§7.1) instead of overshooting when sinusoid
/// amplitudes align.
pub fn peak_offered_cores(app: &App) -> f64 {
    let peak_rate = match &app.arrivals {
        ArrivalProcess::Constant { rate } => *rate,
        ArrivalProcess::Resampled { hi, .. } => *hi,
        ArrivalProcess::Sinusoid { avg, amplitude, .. } => avg + amplitude,
        ArrivalProcess::OnOff { rate, .. } => *rate,
    };
    let total_exec: f64 = app
        .dag
        .functions
        .iter()
        .map(|f| f.exec_time as f64 / SEC as f64)
        .sum();
    peak_rate * total_exec
}

/// Mean offered CPU load of an app in cores (rate × total exec).
pub fn offered_cores(app: &App) -> f64 {
    let mean_rate = match &app.arrivals {
        ArrivalProcess::Constant { rate } => *rate,
        ArrivalProcess::Resampled { lo, hi, .. } => (lo + hi) / 2.0,
        ArrivalProcess::Sinusoid { avg, .. } => *avg,
        ArrivalProcess::OnOff { rate, on, off } => {
            *rate * (*on as f64) / ((*on + *off) as f64)
        }
    };
    let total_exec: f64 = app
        .dag
        .functions
        .iter()
        .map(|f| f.exec_time as f64 / SEC as f64)
        .sum();
    mean_rate * total_exec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c1_c2_single_function_in_table_ranges() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let app = make_app(DagClass::C1, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
            assert_eq!(app.dag.len(), 1);
            let exec = app.dag.functions[0].exec_time;
            assert!((50 * MS..=100 * MS).contains(&exec), "{exec}");
            let slack = app.dag.slack();
            assert!((100 * MS..=150 * MS).contains(&slack), "{slack}");
            let setup = app.dag.functions[0].setup_time;
            assert!((125 * MS..=400 * MS).contains(&setup), "{setup}");

            let app2 = make_app(DagClass::C2, DagId(1), WorkloadKind::W2, 1.0, &mut rng);
            let exec2 = app2.dag.functions[0].exec_time;
            assert!((100 * MS..=200 * MS).contains(&exec2));
            assert!((300 * MS..=500 * MS).contains(&app2.dag.slack()));
        }
    }

    #[test]
    fn c3_is_chain_with_total_exec_in_range() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let app = make_app(DagClass::C3, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
            assert!(app.dag.len() >= 2 && app.dag.len() <= 3);
            // chain: each non-terminal has exactly one child
            for i in 0..app.dag.len() - 1 {
                assert_eq!(app.dag.children[i], vec![(i + 1) as u16]);
            }
            // total within ±stage rounding of 250–400ms
            assert!(app.dag.total_cpl >= 240 * MS && app.dag.total_cpl <= 400 * MS);
            assert!((200 * MS..=300 * MS).contains(&app.dag.slack()));
        }
    }

    #[test]
    fn c4_is_branched_with_constant_arrivals() {
        let mut rng = Rng::new(3);
        let app = make_app(DagClass::C4, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
        assert!(app.dag.len() >= 4, "root + branches + join");
        assert_eq!(app.dag.roots, vec![0]);
        // join has multiple parents
        let join = (app.dag.len() - 1) as usize;
        assert!(app.dag.parent_count[join] >= 2);
        assert!(matches!(app.arrivals, ArrivalProcess::Constant { .. }));
        assert!((500 * MS..=1000 * MS).contains(&app.dag.slack()));
        assert!(!app.class.is_foreground());
    }

    #[test]
    fn w1_uses_resampled_w2_uses_sinusoid() {
        let mut rng = Rng::new(4);
        let a1 = make_app(DagClass::C1, DagId(0), WorkloadKind::W1, 1.0, &mut rng);
        assert!(matches!(a1.arrivals, ArrivalProcess::Resampled { .. }));
        let a2 = make_app(DagClass::C1, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
        assert!(matches!(a2.arrivals, ArrivalProcess::Sinusoid { .. }));
    }

    #[test]
    fn rate_scale_shrinks_offered_load() {
        let mut rng = Rng::new(5);
        let full = make_app(DagClass::C1, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
        let mut rng = Rng::new(5);
        let tenth = make_app(DagClass::C1, DagId(0), WorkloadKind::W2, 0.1, &mut rng);
        let ratio = offered_cores(&tenth) / offered_cores(&full);
        assert!((ratio - 0.1).abs() < 0.02, "ratio {ratio}");
    }

    #[test]
    fn macro_mix_deterministic_and_complete() {
        let a = macro_mix(WorkloadKind::W2, 2, 1.0, 42);
        let b = macro_mix(WorkloadKind::W2, 2, 1.0, 42);
        assert_eq!(a.len(), 8);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.dag.name, y.dag.name);
            assert_eq!(x.dag.deadline, y.dag.deadline);
        }
        // ids dense
        for (i, app) in a.iter().enumerate() {
            assert_eq!(app.dag.id, DagId(i as u32));
        }
        // 2 of each class
        for class in DagClass::ALL {
            assert_eq!(a.iter().filter(|x| x.class == class).count(), 2);
        }
    }

    #[test]
    fn offered_cores_sane() {
        let mut rng = Rng::new(6);
        let app = make_app(DagClass::C4, DagId(0), WorkloadKind::W2, 1.0, &mut rng);
        let cores = offered_cores(&app);
        // 200 RPS × 0.3–0.6s × ~(#fns/3 parallel width ≥ 1) total exec
        assert!(cores > 50.0 && cores < 450.0, "cores {cores}");
    }
}
