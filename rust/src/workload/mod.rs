//! Workload generation: arrival processes, the §7.1 DAG classes, the
//! synthetic SAR app population for the §2.2 characterization figures,
//! and pre-materialized schedules for open-loop wall-clock replay.

pub mod arrival;
pub mod classes;
pub mod sar;
pub mod schedule;

pub use arrival::ArrivalProcess;
pub use classes::{macro_mix, make_app, offered_cores, peak_offered_cores, App, DagClass, WorkloadKind};
pub use schedule::materialize_schedule;
