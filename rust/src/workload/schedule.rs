//! Pre-materialized request schedules: a workload reduced to the
//! driver-agnostic form `Vec<(Micros, DagId)>` — every arrival as a
//! concrete (time, dag) pair.
//!
//! The same W1 resampled-Poisson and W2 sinusoid processes that feed
//! the discrete-event simulator ([`crate::platform::SimPlatform`]) can
//! be replayed against the wall-clock server by walking this vector
//! ([`crate::loadgen`]): the schedule is computed up front, so the
//! replayer spends its time dispatching, not sampling, and two drivers
//! given the same seed see the *same* arrival sequence.
//!
//! The `time_scale` knob stretches the schedule uniformly (2.0 = half
//! the arrival rate, same shape): a laptop-sized stub cluster can
//! replay the paper's traffic shape in slow motion without changing the
//! process statistics. Scale service times and deadlines by the same
//! factor to keep the run self-similar (the loadgen does).

use crate::config::Micros;
use crate::dag::DagId;
use crate::util::rng::Rng;

use super::classes::App;

/// Stretch a virtual time by the schedule's time scale.
pub fn scale_us(t: Micros, time_scale: f64) -> Micros {
    (t as f64 * time_scale).round() as Micros
}

/// Materialize every app's arrival process over `[0, horizon)` (virtual
/// time, *before* scaling), merge, and time-sort. Deterministic per
/// `seed`: each app draws from its own forked stream keyed by its DAG
/// id, so adding an app never perturbs the others' arrivals. Ties are
/// broken by DAG id for a fully deterministic replay order.
pub fn materialize_schedule(
    apps: &[App],
    horizon: Micros,
    time_scale: f64,
    seed: u64,
) -> Vec<(Micros, DagId)> {
    assert!(
        time_scale > 0.0 && time_scale.is_finite(),
        "time_scale must be positive, got {time_scale}"
    );
    let mut entries: Vec<(Micros, DagId)> = Vec::new();
    for app in apps {
        let mut arrivals = app.arrivals.clone();
        // Fresh base per app: the fork depends only on (seed, dag id),
        // never on the app's position in the slice.
        let mut rng = Rng::new(seed).fork(u64::from(app.dag.id.0));
        for t in arrivals.materialize(horizon, &mut rng) {
            entries.push((scale_us(t, time_scale), app.dag.id));
        }
    }
    entries.sort_unstable_by_key(|&(t, dag)| (t, dag.0));
    entries
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SEC;
    use crate::workload::{macro_mix, offered_cores, WorkloadKind};

    fn mix() -> Vec<App> {
        macro_mix(WorkloadKind::W2, 1, 0.01, 42)
    }

    #[test]
    fn schedule_is_sorted_and_deterministic() {
        let a = materialize_schedule(&mix(), 30 * SEC, 1.0, 7);
        let b = materialize_schedule(&mix(), 30 * SEC, 1.0, 7);
        assert_eq!(a, b, "same seed, same schedule");
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].0 <= w[1].0), "time-sorted");
        assert!(a.iter().all(|&(t, _)| t < 30 * SEC));
        let c = materialize_schedule(&mix(), 30 * SEC, 1.0, 8);
        assert_ne!(a, c, "different seed, different schedule");
    }

    #[test]
    fn time_scale_stretches_without_resampling() {
        let one = materialize_schedule(&mix(), 20 * SEC, 1.0, 9);
        let two = materialize_schedule(&mix(), 20 * SEC, 2.0, 9);
        assert_eq!(one.len(), two.len(), "same arrivals, different clock");
        // Entry-by-entry the same (dag, 2×time) — sorting is scale-
        // invariant because scaling is monotone and ties keep dag order.
        for (&(t1, d1), &(t2, d2)) in one.iter().zip(&two) {
            assert_eq!(d1, d2);
            assert_eq!(t2, t1 * 2);
        }
    }

    #[test]
    fn per_dag_rates_track_offered_load() {
        let apps = mix();
        let horizon = 100 * SEC;
        let sched = materialize_schedule(&apps, horizon, 1.0, 5);
        for app in &apps {
            let n = sched.iter().filter(|&&(_, d)| d == app.dag.id).count() as f64;
            let measured_rps = n / 100.0;
            let total_exec: f64 = app
                .dag
                .functions
                .iter()
                .map(|f| f.exec_time as f64 / SEC as f64)
                .sum();
            let expected_rps = offered_cores(app) / total_exec;
            let rel = (measured_rps - expected_rps).abs() / expected_rps.max(1e-9);
            assert!(
                rel < 0.25,
                "dag {} measured {measured_rps:.2} rps vs expected {expected_rps:.2}",
                app.dag.id.0
            );
        }
    }

    #[test]
    fn adding_an_app_does_not_perturb_existing_streams() {
        let apps = mix();
        let full = materialize_schedule(&apps, 20 * SEC, 1.0, 3);
        let first_only = materialize_schedule(&apps[..1], 20 * SEC, 1.0, 3);
        let filtered: Vec<_> = full
            .iter()
            .copied()
            .filter(|&(_, d)| d == apps[0].dag.id)
            .collect();
        assert_eq!(filtered, first_only, "per-app streams are independent");
    }
}
