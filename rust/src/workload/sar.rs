//! Synthetic SAR application population (§2.2, Fig 1–2).
//!
//! The paper characterizes the top-50 deployed apps of the AWS Serverless
//! Application Repository (as of Nov 2019). That dataset is not
//! redistributable, so this generator synthesizes a population matching
//! every statistic the paper reports (DESIGN.md §4 substitution table):
//!
//! * **T1** exec times: 57% < 100 ms; ~10% > 1 s (one ~10 s crawler);
//!   foreground split ~65% < 100 ms, background < 5% < 100 ms.
//! * **T2** code sizes: log-normal, up to ~34 MB.
//! * **T3** SNE (setup / exec): > 1 for 88%+, > 100× for ~37%.
//! * **T4** provisioned memory: 78% at 128 MB; most of the rest leave a
//!   large fraction unused.
//! * **T5** all single-function apps (two 2-chain DAGs exist on SAR; the
//!   platform handles DAGs generally — see `classes.rs`).

use crate::config::{Micros, MS, SEC};
use crate::util::rng::Rng;

/// One synthesized SAR app.
#[derive(Debug, Clone)]
pub struct SarApp {
    pub name: String,
    pub foreground: bool,
    pub exec_time: Micros,
    pub setup_time: Micros,
    pub code_size_kb: u64,
    pub provisioned_mb: u64,
    pub runtime_mb: u64,
    pub language: &'static str,
}

impl SarApp {
    /// Sandbox-setup overhead normalized by execution time (T3).
    pub fn sne(&self) -> f64 {
        self.setup_time as f64 / self.exec_time as f64
    }

    pub fn unused_mem_fraction(&self) -> f64 {
        1.0 - self.runtime_mb as f64 / self.provisioned_mb as f64
    }
}

/// Deterministically synthesize `n` apps (paper studies n = 50).
pub fn synthesize(n: usize, seed: u64) -> Vec<SarApp> {
    let mut rng = Rng::new(seed);
    let mut apps = Vec::with_capacity(n);
    // Language mix from §2.2: 23 NodeJS, 26 Python, 1 Java (of 50).
    let langs: &[(&str, f64)] = &[("nodejs", 0.46), ("python", 0.52), ("java", 0.02)];
    for i in 0..n {
        // ~70% foreground (user-facing) per Fig 2a's split
        let foreground = rng.bool(0.7);
        let exec_time = sample_exec(&mut rng, foreground, i, n);
        // Setup: container + runtime init (log-normal, median ~900 ms —
        // matching prior measurements [39, 40, 49] of multi-second cold
        // starts) plus an S3 code-fetch term (~0.5 ms/KB), yielding the
        // T3 SNE profile.
        let code_size_kb = sample_code_kb(&mut rng);
        let fetch = code_size_kb * MS / 2;
        let base = (rng.lognormal((900.0 * MS as f64).ln(), 1.2) as u64)
            .clamp(100 * MS, 15 * SEC);
        let setup_time = base + fetch;
        let provisioned_mb = if rng.bool(0.78) {
            128
        } else {
            *rng.choose(&[256u64, 512, 1024, 2048])
        };
        // runtime memory: small fraction of provisioned for large allocs
        let runtime_mb = if provisioned_mb == 128 {
            rng.range_u64(40, 128)
        } else {
            rng.range_u64(50, provisioned_mb / 2)
        };
        let language = {
            let x = rng.f64();
            let mut acc = 0.0;
            let mut pick = langs[0].0;
            for (l, p) in langs {
                acc += p;
                if x < acc {
                    pick = l;
                    break;
                }
            }
            pick
        };
        apps.push(SarApp {
            name: format!("sar-app-{i:02}"),
            foreground,
            exec_time,
            setup_time,
            code_size_kb,
            provisioned_mb,
            runtime_mb,
            language,
        });
    }
    apps
}

fn sample_exec(rng: &mut Rng, foreground: bool, i: usize, n: usize) -> Micros {
    // One NYC-PARKS-EVENTS-CRAWLER-style ~10s background app per 50.
    if i == n / 2 {
        return rng.range_u64(9 * SEC, 11 * SEC);
    }
    if foreground {
        // ~65% < 100ms (log-uniform: many single-digit-ms handlers),
        // rest 100ms–1s
        if rng.bool(0.65) {
            let lo = (2.0 * MS as f64).ln();
            let hi = (100.0 * MS as f64).ln();
            rng.range_f64(lo, hi).exp() as u64
        } else if rng.bool(0.9) {
            rng.range_u64(100 * MS, 1 * SEC)
        } else {
            rng.range_u64(1 * SEC, 3 * SEC)
        }
    } else {
        // background: <5% under 100ms, ~30% > 1s
        if rng.bool(0.04) {
            rng.range_u64(50 * MS, 100 * MS)
        } else if rng.bool(0.6) {
            rng.range_u64(100 * MS, 1 * SEC)
        } else {
            rng.range_u64(1 * SEC, 8 * SEC)
        }
    }
}

fn sample_code_kb(rng: &mut Rng) -> u64 {
    // log-normal: median ~300 KB, tail to tens of MB, capped at 34 MB (T2)
    let kb = rng.lognormal(5.7, 1.5);
    (kb as u64).clamp(2, 34 * 1024)
}

/// Population statistics used by the Fig 1/2 harness and the tests.
#[derive(Debug, Clone, Copy)]
pub struct SarStats {
    pub frac_exec_under_100ms: f64,
    pub frac_exec_over_1s: f64,
    pub frac_fg_under_100ms: f64,
    pub frac_bg_under_100ms: f64,
    pub frac_sne_over_1: f64,
    pub frac_sne_over_100: f64,
    pub frac_mem_128: f64,
    pub max_code_kb: u64,
    pub mean_unused_mem_over_128: f64,
}

pub fn stats(apps: &[SarApp]) -> SarStats {
    let n = apps.len() as f64;
    let fg: Vec<&SarApp> = apps.iter().filter(|a| a.foreground).collect();
    let bg: Vec<&SarApp> = apps.iter().filter(|a| !a.foreground).collect();
    let frac = |pred: &dyn Fn(&&SarApp) -> bool, set: &[&SarApp]| {
        if set.is_empty() {
            return 0.0;
        }
        set.iter().filter(|a| pred(a)).count() as f64 / set.len() as f64
    };
    let all: Vec<&SarApp> = apps.iter().collect();
    let over128: Vec<&SarApp> = apps.iter().filter(|a| a.provisioned_mb > 128).collect();
    SarStats {
        frac_exec_under_100ms: frac(&|a| a.exec_time < 100 * MS, &all),
        frac_exec_over_1s: frac(&|a| a.exec_time > SEC, &all),
        frac_fg_under_100ms: frac(&|a| a.exec_time < 100 * MS, &fg),
        frac_bg_under_100ms: frac(&|a| a.exec_time < 100 * MS, &bg),
        frac_sne_over_1: frac(&|a| a.sne() > 1.0, &all),
        frac_sne_over_100: frac(&|a| a.sne() > 100.0, &all),
        frac_mem_128: apps.iter().filter(|a| a.provisioned_mb == 128).count() as f64 / n,
        max_code_kb: apps.iter().map(|a| a.code_size_kb).max().unwrap_or(0),
        mean_unused_mem_over_128: if over128.is_empty() {
            0.0
        } else {
            over128.iter().map(|a| a.unused_mem_fraction()).sum::<f64>()
                / over128.len() as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn population() -> Vec<SarApp> {
        // large n for stable fractions; the figure harness uses n=50
        synthesize(2000, 1)
    }

    #[test]
    fn t1_exec_time_profile() {
        let s = stats(&population());
        assert!(
            (s.frac_exec_under_100ms - 0.5).abs() < 0.15,
            "57% target, got {}",
            s.frac_exec_under_100ms
        );
        assert!(
            s.frac_exec_over_1s > 0.05 && s.frac_exec_over_1s < 0.25,
            "~10% target, got {}",
            s.frac_exec_over_1s
        );
        assert!(s.frac_fg_under_100ms > 0.5, "{}", s.frac_fg_under_100ms);
        assert!(s.frac_bg_under_100ms < 0.1, "{}", s.frac_bg_under_100ms);
    }

    #[test]
    fn t2_code_sizes_bounded_at_34mb() {
        let apps = population();
        let s = stats(&apps);
        assert!(s.max_code_kb <= 34 * 1024);
        assert!(s.max_code_kb > 1024, "tail should reach MBs");
        // median should be modest (sub-MB)
        let mut sizes: Vec<u64> = apps.iter().map(|a| a.code_size_kb).collect();
        sizes.sort_unstable();
        assert!(sizes[sizes.len() / 2] < 1024);
    }

    #[test]
    fn t3_sne_dominates() {
        let s = stats(&population());
        assert!(s.frac_sne_over_1 > 0.80, "88% target, got {}", s.frac_sne_over_1);
        assert!(
            s.frac_sne_over_100 > 0.0 && s.frac_sne_over_100 < 0.6,
            "37% ballpark, got {}",
            s.frac_sne_over_100
        );
    }

    #[test]
    fn t4_memory_profile() {
        let apps = population();
        let s = stats(&apps);
        assert!((s.frac_mem_128 - 0.78).abs() < 0.05, "{}", s.frac_mem_128);
        assert!(
            s.mean_unused_mem_over_128 > 0.4,
            "large provisions mostly unused: {}",
            s.mean_unused_mem_over_128
        );
        for a in &apps {
            assert!(a.runtime_mb <= a.provisioned_mb);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = synthesize(50, 7);
        let b = synthesize(50, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.exec_time, y.exec_time);
            assert_eq!(x.code_size_kb, y.code_size_kb);
        }
        let c = synthesize(50, 8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.exec_time != y.exec_time));
    }

    #[test]
    fn language_mix_present() {
        let apps = population();
        for lang in ["nodejs", "python"] {
            assert!(apps.iter().any(|a| a.language == lang));
        }
    }
}
