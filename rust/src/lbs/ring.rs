//! Consistent-hash ring over SGSs (§5.2.2 "Initial SGS Selection").
//!
//! Each SGS is hashed onto the ring at `vnodes` positions (virtual nodes
//! smooth the key distribution); a DAG's initial SGS is the first ring
//! position clockwise of the DAG-id hash. Scale-out walks further
//! clockwise ("the next one in the ring"), so each DAG has a
//! deterministic SGS acquisition order with distinct DAGs starting at
//! spread-out points — no single SGS is responsible for a large share of
//! DAGs.

use crate::sgs::SgsId;

fn mix64(mut x: u64) -> u64 {
    // splitmix64 finalizer — good avalanche for ring positions
    x = x.wrapping_add(0x9E3779B97F4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

/// The ring: sorted (position, sgs) pairs.
#[derive(Debug, Clone)]
pub struct HashRing {
    points: Vec<(u64, SgsId)>,
}

impl HashRing {
    pub fn new(sgs_count: usize, vnodes: usize) -> Self {
        assert!(sgs_count > 0 && vnodes > 0);
        let mut points = Vec::with_capacity(sgs_count * vnodes);
        for s in 0..sgs_count as u16 {
            for v in 0..vnodes as u64 {
                let pos = mix64((s as u64) << 32 | v);
                points.push((pos, SgsId(s)));
            }
        }
        points.sort_unstable();
        HashRing { points }
    }

    fn dag_hash(dag_key: u64) -> u64 {
        mix64(dag_key ^ 0xD1A6_0000_0000_0000)
    }

    /// The initial SGS for a DAG.
    pub fn primary(&self, dag_key: u64) -> SgsId {
        self.successors(dag_key)
            .next()
            .expect("non-empty ring")
    }

    /// Clockwise walk from the DAG's ring position yielding each distinct
    /// SGS once — the scale-out acquisition order.
    pub fn successors(&self, dag_key: u64) -> impl Iterator<Item = SgsId> + '_ {
        let h = Self::dag_hash(dag_key);
        let start = self.points.partition_point(|(p, _)| *p < h);
        let n = self.points.len();
        let mut seen = Vec::new();
        (0..n).filter_map(move |i| {
            let (_, s) = self.points[(start + i) % n];
            if seen.contains(&s) {
                None
            } else {
                seen.push(s);
                Some(s)
            }
        })
    }

    /// Number of distinct SGSs on the ring.
    pub fn sgs_count(&self) -> usize {
        let mut ids: Vec<u16> = self.points.iter().map(|(_, s)| s.0).collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primary_is_deterministic() {
        let ring = HashRing::new(8, 32);
        assert_eq!(ring.primary(42), ring.primary(42));
    }

    #[test]
    fn successors_cover_all_sgs_exactly_once() {
        let ring = HashRing::new(8, 32);
        let order: Vec<SgsId> = ring.successors(7).collect();
        assert_eq!(order.len(), 8);
        let mut ids: Vec<u16> = order.iter().map(|s| s.0).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..8).collect::<Vec<u16>>());
        // first successor == primary
        assert_eq!(order[0], ring.primary(7));
    }

    #[test]
    fn distribution_is_roughly_balanced() {
        // "no single SGS is overwhelmed by being responsible for a large
        // share of DAGs" — with 8 SGSs and 4096 DAGs, each should get a
        // share within 3x of fair.
        let ring = HashRing::new(8, 64);
        let mut counts = [0usize; 8];
        for dag in 0..4096u64 {
            counts[ring.primary(dag).0 as usize] += 1;
        }
        let fair = 4096 / 8;
        for (i, c) in counts.iter().enumerate() {
            assert!(
                *c > fair / 3 && *c < fair * 3,
                "sgs {i} got {c} (fair {fair}): {counts:?}"
            );
        }
    }

    #[test]
    fn different_dags_get_spread_out_primaries() {
        let ring = HashRing::new(4, 32);
        let primaries: std::collections::HashSet<u16> =
            (0..64u64).map(|d| ring.primary(d).0).collect();
        assert_eq!(primaries.len(), 4, "all SGSs used as primaries");
    }

    #[test]
    fn single_sgs_ring() {
        let ring = HashRing::new(1, 8);
        assert_eq!(ring.primary(123), SgsId(0));
        assert_eq!(ring.successors(123).count(), 1);
        assert_eq!(ring.sgs_count(), 1);
    }
}
