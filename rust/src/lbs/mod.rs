//! The Load Balancing Service (§5): routes each incoming DAG request to
//! one of the SGSs associated with that DAG, and scales the association
//! set per DAG.
//!
//! Responsibilities (§5.1): (1) keep any single SGS from becoming a
//! hotspot, (2) sandbox-aware routing so requests land where proactive
//! sandboxes exist. Both are served by the same machinery: consistent
//! hashing for initial placement ([`ring`]), lottery routing weighted by
//! per-SGS sandbox counts ([`lottery`]), and the queuing-delay-driven
//! scaling loop ([`scaling`], Pseudocode 2) with gradual ramp-up
//! (ticket floor of 1) and gradual drain (removed list with discounted
//! tickets).

pub mod lottery;
pub mod ring;
pub mod scaling;

use std::collections::{HashMap, HashSet};

use crate::config::{LbsConfig, Micros, ScaleOutMode};
use crate::dag::DagId;
use crate::sgs::SgsId;
use crate::util::rng::Rng;

pub use ring::HashRing;
pub use scaling::{ScaleDecision, SgsReport};

/// Control-plane actions the LBS asks the platform to carry out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScaleAction {
    /// Associate `sgs` with `dag`; prime it with `prime_target`
    /// proactive sandboxes per function (the mean across active SGSs)
    /// and seed its rate estimate.
    Out {
        dag: DagId,
        sgs: SgsId,
        prime_target: u32,
        expected_rate: f64,
    },
    /// Move `sgs` to the DAG's removed list (gradual drain).
    In { dag: DagId, sgs: SgsId },
    /// Fully dissociate a drained SGS (platform calls
    /// `Sgs::release_dag`).
    Drop { dag: DagId, sgs: SgsId },
    /// Reset qdelay windows at every SGS associated with `dag` (after
    /// any scaling action, §5.2.2).
    ResetWindows { dag: DagId },
}

/// Per-DAG routing state.
#[derive(Debug)]
struct DagRouting {
    /// Hash key used on the ring (stable per DAG).
    key: u64,
    /// Associated SGSs in acquisition order; last = most recently added.
    active: Vec<SgsId>,
    /// Scaled-in SGSs still draining, with control ticks spent there.
    removed: Vec<(SgsId, u32)>,
    /// Latest piggybacked report per SGS.
    reports: HashMap<SgsId, SgsReport>,
    /// Consecutive control evaluations below the scale-in threshold;
    /// scale-in fires only after [`SCALE_IN_HYSTERESIS`] of them — the
    /// paper's anti-oscillation intent ("we keep the scale-in threshold
    /// well below the scale-out threshold") made robust for workloads
    /// whose troughs reach near-zero queuing within one control tick.
    in_streak: u32,
}

/// How many control ticks a removed SGS may linger before forced drop.
const REMOVED_DROP_TICKS: u32 = 20;

/// Consecutive below-SIT evaluations required before scaling in.
const SCALE_IN_HYSTERESIS: u32 = 30;

/// The load balancing service.
#[derive(Debug)]
pub struct Lbs {
    cfg: LbsConfig,
    ring: HashRing,
    dags: HashMap<DagId, DagRouting>,
    /// Fail-stopped SGSs (§6.1): excluded from placement and scale-out
    /// until a replacement instance re-registers.
    dead: HashSet<SgsId>,
    rng: Rng,
    routes: u64,
    scale_outs: u64,
    scale_ins: u64,
}

impl Lbs {
    pub fn new(cfg: LbsConfig, sgs_count: usize, seed: u64) -> Self {
        let ring = HashRing::new(sgs_count, cfg.ring_vnodes);
        Lbs {
            cfg,
            ring,
            dags: HashMap::new(),
            dead: HashSet::new(),
            rng: Rng::new(seed ^ 0x1b5),
            routes: 0,
            scale_outs: 0,
            scale_ins: 0,
        }
    }

    pub fn config(&self) -> &LbsConfig {
        &self.cfg
    }

    pub fn routes(&self) -> u64 {
        self.routes
    }

    pub fn scale_outs(&self) -> u64 {
        self.scale_outs
    }

    pub fn scale_ins(&self) -> u64 {
        self.scale_ins
    }

    /// First request for a DAG: assign its initial SGS via the ring
    /// (skipping fail-stopped SGSs).
    pub fn register_dag(&mut self, dag: DagId) -> SgsId {
        let key = dag.0 as u64;
        let dead = &self.dead;
        let primary = self
            .ring
            .successors(key)
            .find(|s| !dead.contains(s))
            .expect("at least one live SGS");
        self.dags.entry(dag).or_insert_with(|| DagRouting {
            key,
            active: vec![primary],
            removed: Vec::new(),
            reports: HashMap::new(),
            in_streak: 0,
        });
        primary
    }

    /// SGSs currently associated with a DAG (active list).
    pub fn active_sgs(&self, dag: DagId) -> &[SgsId] {
        self.dags
            .get(&dag)
            .map(|d| d.active.as_slice())
            .unwrap_or(&[])
    }

    /// SGSs on the removed (draining) list.
    pub fn removed_sgs(&self, dag: DagId) -> Vec<SgsId> {
        self.dags
            .get(&dag)
            .map(|d| d.removed.iter().map(|(s, _)| *s).collect())
            .unwrap_or_default()
    }

    /// Route one request (§5.2.3). A DAG never seen before is
    /// auto-registered to its ring primary — routing is total, so a
    /// race between upload and first request (or a caller skipping
    /// [`Self::register_dag`]) degrades to first-touch registration
    /// instead of a panic that takes the server down.
    pub fn route(&mut self, dag: DagId) -> SgsId {
        self.routes += 1;
        if !self.dags.contains_key(&dag) {
            self.register_dag(dag);
        }
        let d = self.dags.get(&dag).expect("registered above");
        let choice = match self.cfg.scale_out_mode {
            ScaleOutMode::Gradual => {
                let entry = |s: &SgsId| {
                    let r = d.reports.get(s);
                    (
                        *s,
                        r.map(|r| r.sandboxes).unwrap_or(0),
                        r.map(|r| r.qdelay_us).unwrap_or(0.0),
                    )
                };
                let active: Vec<(SgsId, u32, f64)> = d.active.iter().map(entry).collect();
                let removed: Vec<(SgsId, u32, f64)> =
                    d.removed.iter().map(|(s, _)| entry(s)).collect();
                let table = lottery::ticket_table(&active, &removed, self.cfg.removed_discount);
                lottery::draw(&table, &mut self.rng)
            }
            ScaleOutMode::Instant => lottery::draw_uniform(&d.active, &mut self.rng),
        };
        choice
    }

    /// Ingest a piggybacked per-SGS report for a DAG.
    pub fn update_report(&mut self, dag: DagId, report: SgsReport) {
        if let Some(d) = self.dags.get_mut(&dag) {
            d.reports.insert(report.sgs, report);
        }
    }

    /// Periodic control evaluation for one DAG (Pseudocode 2 +
    /// removed-list maintenance). `slack` is the DAG's static slack.
    pub fn control_tick(&mut self, dag: DagId, slack: Micros) -> Vec<ScaleAction> {
        let sgs_total = self.ring.sgs_count();
        let Some(d) = self.dags.get_mut(&dag) else {
            return Vec::new();
        };
        let mut actions = Vec::new();

        // Removed-list maintenance: drop SGSs that have drained (their
        // sandbox count decayed to zero) or lingered too long.
        d.removed = {
            let reports = &d.reports;
            let mut keep = Vec::new();
            for (sgs, ticks) in d.removed.drain(..) {
                let sandboxes = reports.get(&sgs).map(|r| r.sandboxes).unwrap_or(0);
                if sandboxes == 0 || ticks + 1 >= REMOVED_DROP_TICKS {
                    actions.push(ScaleAction::Drop { dag, sgs });
                } else {
                    keep.push((sgs, ticks + 1));
                }
            }
            keep
        };

        // Gather reports for the active set; an SGS we have never heard
        // from reports an unfilled window (gating the decision).
        let reports: Vec<SgsReport> = d
            .active
            .iter()
            .map(|s| {
                d.reports.get(s).copied().unwrap_or(SgsReport {
                    sgs: *s,
                    sandboxes: 0,
                    qdelay_us: 0.0,
                    window_full: false,
                })
            })
            .collect();
        let (_metric, decision) = scaling::evaluate(
            &reports,
            slack,
            self.cfg.scale_out_threshold,
            self.cfg.scale_in_threshold,
        );
        match decision {
            ScaleDecision::Out => {
                d.in_streak = 0;
                // Revive a draining SGS first — it still has sandboxes.
                if let Some(pos) = d.removed.iter().position(|_| true) {
                    let (sgs, _) = d.removed.remove(pos);
                    d.active.push(sgs);
                    self.scale_outs += 1;
                    actions.push(ScaleAction::ResetWindows { dag });
                } else if d.active.len() < sgs_total - self.dead.len() {
                    // Next live SGS clockwise on the ring not already
                    // active.
                    let key = d.key;
                    let dead = &self.dead;
                    let next = self
                        .ring
                        .successors(key)
                        .find(|s| !d.active.contains(s) && !dead.contains(s));
                    if let Some(sgs) = next {
                        let total_sandboxes: u32 = reports.iter().map(|r| r.sandboxes).sum();
                        let n_after = (d.active.len() + 1) as u32;
                        let prime_target = (total_sandboxes / n_after).max(1);
                        d.active.push(sgs);
                        self.scale_outs += 1;
                        // Seed the new SGS's rate so inv_cdf(sla, rate)
                        // lands near the prime target.
                        let expected_rate = (f64::from(prime_target) * 0.75).max(0.5);
                        actions.push(ScaleAction::Out {
                            dag,
                            sgs,
                            prime_target,
                            expected_rate,
                        });
                        actions.push(ScaleAction::ResetWindows { dag });
                    }
                }
            }
            ScaleDecision::In => {
                d.in_streak += 1;
                if d.in_streak >= SCALE_IN_HYSTERESIS && d.active.len() > 1 {
                    d.in_streak = 0;
                    let sgs = d.active.pop().expect("len > 1");
                    d.removed.push((sgs, 0));
                    self.scale_ins += 1;
                    actions.push(ScaleAction::In { dag, sgs });
                    actions.push(ScaleAction::ResetWindows { dag });
                }
            }
            ScaleDecision::Hold => {
                d.in_streak = 0;
            }
        }
        actions
    }

    /// Fail-stop an SGS (§6.1): remove it from every DAG's active and
    /// removed lists, substituting the next live ring successor when a
    /// DAG would otherwise have no active SGS. Returns the DAGs whose
    /// active set changed.
    pub fn remove_sgs(&mut self, failed: SgsId) -> Vec<DagId> {
        self.dead.insert(failed);
        let ring = &self.ring;
        let dead = &self.dead;
        let mut affected = Vec::new();
        for (dag, d) in self.dags.iter_mut() {
            let before = d.active.len();
            d.active.retain(|s| *s != failed);
            d.removed.retain(|(s, _)| *s != failed);
            d.reports.remove(&failed);
            if d.active.is_empty() {
                let replacement = ring
                    .successors(d.key)
                    .find(|s| !dead.contains(s))
                    .expect("cluster has at least one live SGS");
                d.active.push(replacement);
            }
            if d.active.len() != before {
                affected.push(*dag);
            }
        }
        affected.sort();
        affected
    }

    /// A replacement SGS instance came online for a failed slot (§6.1:
    /// state recovered from the external store).
    pub fn restore_sgs(&mut self, sgs: SgsId) {
        self.dead.remove(&sgs);
    }

    /// Current scaling metric for observability (Fig 10/11 plots).
    pub fn current_metric(&self, dag: DagId, slack: Micros) -> f64 {
        let Some(d) = self.dags.get(&dag) else {
            return 0.0;
        };
        let reports: Vec<SgsReport> = d
            .active
            .iter()
            .filter_map(|s| d.reports.get(s).copied())
            .collect();
        scaling::scaling_metric(&reports, slack)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn lbs(sgs: usize) -> Lbs {
        Lbs::new(LbsConfig::default(), sgs, 7)
    }

    fn full_report(sgs: SgsId, sandboxes: u32, qdelay_us: f64) -> SgsReport {
        SgsReport {
            sgs,
            sandboxes,
            qdelay_us,
            window_full: true,
        }
    }

    #[test]
    fn register_assigns_ring_primary_stably() {
        let mut l = lbs(8);
        let a = l.register_dag(DagId(1));
        let b = l.register_dag(DagId(1));
        assert_eq!(a, b);
        assert_eq!(l.active_sgs(DagId(1)), &[a]);
    }

    #[test]
    fn route_before_register_auto_registers() {
        // Regression: this used to panic ("route before register_dag")
        // and take the realtime server down with it.
        let mut l = lbs(4);
        let s = l.route(DagId(7));
        assert_eq!(l.active_sgs(DagId(7)), &[s], "first touch registered");
        // stable afterwards: the same single-SGS association routes
        // every subsequent request
        for _ in 0..10 {
            assert_eq!(l.route(DagId(7)), s);
        }
        // and matches what explicit registration would have picked
        let mut l2 = lbs(4);
        assert_eq!(l2.register_dag(DagId(7)), s);
    }

    #[test]
    fn route_single_sgs() {
        let mut l = lbs(4);
        let s = l.register_dag(DagId(0));
        for _ in 0..10 {
            assert_eq!(l.route(DagId(0)), s);
        }
        assert_eq!(l.routes(), 10);
    }

    #[test]
    fn scale_out_adds_next_ring_sgs_and_primes() {
        let mut l = lbs(8);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 10, 100_000.0));
        // metric = 100ms / 100ms slack = 1.0 > 0.3 → Out
        let actions = l.control_tick(DagId(0), 100 * MS);
        let out = actions
            .iter()
            .find_map(|a| match a {
                ScaleAction::Out {
                    sgs, prime_target, ..
                } => Some((*sgs, *prime_target)),
                _ => None,
            })
            .expect("scale out");
        assert_ne!(out.0, s0);
        assert_eq!(out.1, 5, "mean of 10 sandboxes over 2 SGSs");
        assert_eq!(l.active_sgs(DagId(0)).len(), 2);
        assert!(actions.contains(&ScaleAction::ResetWindows { dag: DagId(0) }));
        assert_eq!(l.scale_outs(), 1);
    }

    #[test]
    fn window_reset_gates_consecutive_scale_outs() {
        let mut l = lbs(8);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 10, 100_000.0));
        assert!(!l.control_tick(DagId(0), 100 * MS).is_empty());
        // the new SGS has no report → window not full → Hold
        let actions = l.control_tick(DagId(0), 100 * MS);
        assert!(
            actions.iter().all(|a| matches!(a, ScaleAction::Drop { .. })),
            "gated until new SGS reports: {actions:?}"
        );
    }

    #[test]
    fn scale_in_moves_to_removed_then_drops() {
        let mut l = lbs(8);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 10, 200_000.0));
        l.control_tick(DagId(0), 100 * MS); // out
        let s1 = *l.active_sgs(DagId(0)).last().unwrap();
        // both idle now; scale-in needs a sustained streak (hysteresis)
        l.update_report(DagId(0), full_report(s0, 10, 100.0));
        l.update_report(DagId(0), full_report(s1, 10, 100.0));
        let mut actions = Vec::new();
        for _ in 0..SCALE_IN_HYSTERESIS + 1 {
            actions = l.control_tick(DagId(0), 100 * MS);
            if !actions.is_empty() {
                break;
            }
        }
        assert!(actions.contains(&ScaleAction::In { dag: DagId(0), sgs: s1 }));
        assert_eq!(l.active_sgs(DagId(0)).len(), 1);
        assert_eq!(l.removed_sgs(DagId(0)), vec![s1]);
        // drained: report zero sandboxes → dropped on next tick
        l.update_report(DagId(0), full_report(s1, 0, 100.0));
        let actions = l.control_tick(DagId(0), 100 * MS);
        assert!(actions.contains(&ScaleAction::Drop { dag: DagId(0), sgs: s1 }));
        assert!(l.removed_sgs(DagId(0)).is_empty());
    }

    #[test]
    fn removed_sgs_still_draws_discounted_traffic() {
        let mut cfg = LbsConfig::default();
        cfg.removed_discount = 0.5;
        let mut l = Lbs::new(cfg, 8, 7);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 8, 200_000.0));
        l.control_tick(DagId(0), 100 * MS); // out → s1
        let s1 = *l.active_sgs(DagId(0)).last().unwrap();
        l.update_report(DagId(0), full_report(s0, 8, 10.0));
        l.update_report(DagId(0), full_report(s1, 8, 10.0));
        for _ in 0..SCALE_IN_HYSTERESIS + 1 {
            l.control_tick(DagId(0), 100 * MS); // in (after hysteresis)
        }
        assert_eq!(l.removed_sgs(DagId(0)), vec![s1]);
        // s1 keeps 8 × 0.5 = 4 tickets vs s0's 8 → about a third
        let hits = (0..10_000).filter(|_| l.route(DagId(0)) == s1).count();
        assert!(hits > 2_000 && hits < 4_500, "gradual drain share: {hits}");
    }

    #[test]
    fn scale_out_revives_draining_sgs_first() {
        let mut l = lbs(8);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 8, 200_000.0));
        l.control_tick(DagId(0), 100 * MS);
        let s1 = *l.active_sgs(DagId(0)).last().unwrap();
        l.update_report(DagId(0), full_report(s0, 8, 10.0));
        l.update_report(DagId(0), full_report(s1, 8, 10.0));
        for _ in 0..SCALE_IN_HYSTERESIS + 1 {
            l.control_tick(DagId(0), 100 * MS); // in (after hysteresis)
        }
        assert_eq!(l.removed_sgs(DagId(0)), vec![s1]);
        // load returns before the drain finishes
        l.update_report(DagId(0), full_report(s0, 8, 300_000.0));
        let actions = l.control_tick(DagId(0), 100 * MS);
        // revival: no Out action (no priming needed), s1 back in active
        assert!(actions.iter().all(|a| !matches!(a, ScaleAction::Out { .. })));
        assert!(l.active_sgs(DagId(0)).contains(&s1));
        assert!(l.removed_sgs(DagId(0)).is_empty());
    }

    #[test]
    fn cannot_scale_beyond_cluster() {
        let mut l = lbs(2);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 4, 500_000.0));
        l.control_tick(DagId(0), 100 * MS);
        let s1 = *l.active_sgs(DagId(0)).last().unwrap();
        l.update_report(DagId(0), full_report(s0, 4, 500_000.0));
        l.update_report(DagId(0), full_report(s1, 4, 500_000.0));
        let actions = l.control_tick(DagId(0), 100 * MS);
        assert!(actions.is_empty(), "no third SGS exists: {actions:?}");
        assert_eq!(l.active_sgs(DagId(0)).len(), 2);
    }

    #[test]
    fn never_scales_in_below_one() {
        let mut l = lbs(4);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 4, 0.0));
        let actions = l.control_tick(DagId(0), 100 * MS);
        assert!(actions.is_empty());
        assert_eq!(l.active_sgs(DagId(0)).len(), 1);
    }

    #[test]
    fn instant_mode_routes_uniformly() {
        let mut cfg = LbsConfig::default();
        cfg.scale_out_mode = ScaleOutMode::Instant;
        let mut l = Lbs::new(cfg, 8, 3);
        let s0 = l.register_dag(DagId(0));
        l.update_report(DagId(0), full_report(s0, 100, 200_000.0));
        l.control_tick(DagId(0), 100 * MS); // out
        let s1 = *l.active_sgs(DagId(0)).last().unwrap();
        // uniform: new SGS gets ~half instantly despite 0 sandboxes
        let hits = (0..10_000).filter(|_| l.route(DagId(0)) == s1).count();
        assert!(hits > 4_500 && hits < 5_500, "instant share {hits}");
    }
}
