//! Sandbox-aware lottery routing (§5.2.3).
//!
//! Among the SGSs associated with a DAG, each request is routed by a
//! lottery draw where an SGS's tickets equal the number of proactive
//! sandboxes it holds for the DAG — so request share tracks capacity as
//! the new SGS warms up (gradual scale-out). A freshly added SGS starts
//! at 1 ticket ("we initialize the tickets for the new SGS with a small
//! value (say 1) so that requests go to it"). SGSs on the *removed* list
//! still receive tickets, scaled by a discount factor, so scale-in is
//! gradual too.

use crate::sgs::SgsId;
use crate::util::rng::Rng;

/// One SGS's entry in a DAG's lottery.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TicketEntry {
    pub sgs: SgsId,
    pub tickets: f64,
}

/// Congestion damping: an SGS reporting queuing delay `q` (µs) has its
/// tickets scaled by `1 / (1 + q/10ms)`. Without this, sandbox-count
/// tickets form a positive feedback loop (more traffic → higher local
/// demand estimate → more sandboxes → more tickets) with no restoring
/// force, and one SGS saturates while its peers idle — violating the
/// LBS's §5.1 responsibility to "ensure that ... a single SGS does not
/// become a bottleneck". The damping uses only the queuing delay the
/// SGSs already piggyback (§5.2.1).
const QDELAY_DAMP_US: f64 = 10_000.0;

fn damp(qdelay_us: f64) -> f64 {
    1.0 / (1.0 + (qdelay_us.max(0.0) / QDELAY_DAMP_US))
}

/// Build the ticket table for a DAG: active SGSs get
/// `max(1, sandbox_count)` tickets damped by reported queuing delay;
/// removed SGSs get their damped count scaled by `discount`.
pub fn ticket_table(
    active: &[(SgsId, u32, f64)],
    removed: &[(SgsId, u32, f64)],
    discount: f64,
) -> Vec<TicketEntry> {
    let mut out = Vec::with_capacity(active.len() + removed.len());
    for &(sgs, sandboxes, qdelay_us) in active {
        out.push(TicketEntry {
            sgs,
            tickets: f64::from(sandboxes.max(1)) * damp(qdelay_us),
        });
    }
    for &(sgs, sandboxes, qdelay_us) in removed {
        let t = f64::from(sandboxes) * damp(qdelay_us) * discount;
        if t > 0.0 {
            out.push(TicketEntry { sgs, tickets: t });
        }
    }
    out
}

/// Draw the routing lottery. Panics on an empty table (a DAG always has
/// at least one active SGS).
pub fn draw(table: &[TicketEntry], rng: &mut Rng) -> SgsId {
    assert!(!table.is_empty(), "lottery over zero SGSs");
    if table.len() == 1 {
        return table[0].sgs;
    }
    let weights: Vec<f64> = table.iter().map(|t| t.tickets).collect();
    table[rng.weighted_choice(&weights)].sgs
}

/// Instant-mode routing (ablation §7.3.2): uniform over active SGSs,
/// ignoring sandbox counts.
pub fn draw_uniform(active: &[SgsId], rng: &mut Rng) -> SgsId {
    assert!(!active.is_empty());
    *rng.choose(active)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn active_min_one_ticket() {
        let t = ticket_table(&[(SgsId(0), 0, 0.0), (SgsId(1), 10, 0.0)], &[], 0.25);
        assert_eq!(t[0].tickets, 1.0);
        assert_eq!(t[1].tickets, 10.0);
    }

    #[test]
    fn removed_discounted_and_zero_dropped() {
        let t = ticket_table(
            &[(SgsId(0), 4, 0.0)],
            &[(SgsId(1), 8, 0.0), (SgsId(2), 0, 0.0)],
            0.25,
        );
        assert_eq!(t.len(), 2);
        assert_eq!(t[1].sgs, SgsId(1));
        assert_eq!(t[1].tickets, 2.0);
    }

    #[test]
    fn congested_sgs_loses_ticket_share() {
        // equal sandboxes, one SGS reporting 90ms queueing → ~10x fewer
        // tickets; this is the anti-hotspot restoring force (§5.1).
        let t = ticket_table(
            &[(SgsId(0), 10, 0.0), (SgsId(1), 10, 90_000.0)],
            &[],
            0.25,
        );
        assert!(t[0].tickets / t[1].tickets > 8.0, "{t:?}");
    }

    #[test]
    fn draw_share_tracks_tickets() {
        let t = ticket_table(&[(SgsId(0), 9, 0.0), (SgsId(1), 1, 0.0)], &[], 0.25);
        let mut rng = Rng::new(42);
        let mut counts = [0u32; 2];
        for _ in 0..20_000 {
            counts[draw(&t, &mut rng).0 as usize] += 1;
        }
        let share = counts[0] as f64 / 20_000.0;
        assert!((share - 0.9).abs() < 0.02, "share {share}");
    }

    #[test]
    fn new_sgs_receives_some_traffic_immediately() {
        // freshly added SGS with 0 sandboxes still gets ~1/(N+1) of a
        // well-provisioned DAG's traffic via its floor ticket
        let t = ticket_table(&[(SgsId(0), 99, 0.0), (SgsId(1), 0, 0.0)], &[], 0.25);
        let mut rng = Rng::new(7);
        let hits = (0..50_000)
            .filter(|_| draw(&t, &mut rng) == SgsId(1))
            .count();
        assert!(hits > 200, "new SGS starved: {hits}");
    }

    #[test]
    fn uniform_mode_ignores_sandboxes() {
        let active = [SgsId(0), SgsId(1), SgsId(2)];
        let mut rng = Rng::new(3);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[draw_uniform(&active, &mut rng).0 as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn single_entry_fast_path() {
        let t = ticket_table(&[(SgsId(5), 0, 0.0)], &[], 0.5);
        let mut rng = Rng::new(1);
        assert_eq!(draw(&t, &mut rng), SgsId(5));
    }
}
