//! Per-DAG SGS scaling (§5.2, Pseudocode 2).
//!
//! The universal scaling indicator is the queuing delay requests of a DAG
//! experience at each associated SGS. The LBS computes
//!
//! ```text
//! weightedQDelay = Σᵢ Nᵢ·qᵢ / Σᵢ Nᵢ        (sandbox-weighted mean)
//! scalingMetric  = weightedQDelay / slack(d)  (deadline-aware normalize)
//! ```
//!
//! and scales out when the metric exceeds `ScaleOutThreshold` (0.3 in
//! §7.5), in when it falls below the (much lower) scale-in threshold.
//! Decisions are gated on every associated SGS's qdelay window being
//! full, and windows are reset after each action so the next decision
//! observes post-action behaviour — both prevent reacting to transients.

use crate::config::Micros;
use crate::sgs::SgsId;

/// One SGS's piggybacked report for a DAG (§5.2.1: measured queuing
/// delay + sandbox count ride on responses to the LBS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SgsReport {
    pub sgs: SgsId,
    /// Proactive sandbox count for this DAG at the SGS (the weight Nᵢ).
    pub sandboxes: u32,
    /// Smoothed queuing delay (µs) for this DAG at the SGS.
    pub qdelay_us: f64,
    /// Whether the SGS's qdelay window has filled since the last reset.
    pub window_full: bool,
}

/// Scaling decision for one DAG at one control evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    /// Associate one more SGS.
    Out,
    /// Dissociate the most recently added SGS.
    In,
    /// Leave the association unchanged.
    Hold,
}

/// Pseudocode 2: compute the metric and compare against thresholds.
///
/// `slack` is the DAG's static slack budget (deadline − critical-path
/// exec); the normalization is what makes low-slack DAGs scale out more
/// aggressively (Fig 10).
pub fn evaluate(
    reports: &[SgsReport],
    slack: Micros,
    scale_out_threshold: f64,
    scale_in_threshold: f64,
) -> (f64, ScaleDecision) {
    let metric = scaling_metric(reports, slack);
    let decision = if !reports.iter().all(|r| r.window_full) {
        // §5.2.2: only decide once the observation windows are filled.
        ScaleDecision::Hold
    } else if metric > scale_out_threshold {
        ScaleDecision::Out
    } else if metric < scale_in_threshold {
        ScaleDecision::In
    } else {
        ScaleDecision::Hold
    };
    (metric, decision)
}

/// The raw metric (exposed for tests/benches and the §7.4 overhead
/// bench).
pub fn scaling_metric(reports: &[SgsReport], slack: Micros) -> f64 {
    if reports.is_empty() {
        return 0.0;
    }
    let total_n: f64 = reports.iter().map(|r| f64::from(r.sandboxes.max(1))).sum();
    let weighted: f64 = reports
        .iter()
        .map(|r| f64::from(r.sandboxes.max(1)) * r.qdelay_us)
        .sum();
    let weighted_qdelay = weighted / total_n;
    // Guard: a DAG whose deadline equals its critical path has no slack;
    // normalize by at least 1ms to keep the metric finite (such DAGs
    // scale out at the slightest queuing).
    let slack_us = (slack as f64).max(1_000.0);
    weighted_qdelay / slack_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MS;

    fn rep(sgs: u16, n: u32, q: f64, full: bool) -> SgsReport {
        SgsReport {
            sgs: SgsId(sgs),
            sandboxes: n,
            qdelay_us: q,
            window_full: full,
        }
    }

    #[test]
    fn metric_is_sandbox_weighted() {
        // SGS0: 9 sandboxes @ 100µs, SGS1: 1 sandbox @ 1000µs
        let reports = [rep(0, 9, 100.0, true), rep(1, 1, 1000.0, true)];
        let m = scaling_metric(&reports, 100 * MS);
        // weighted mean = (900 + 1000)/10 = 190µs; / 100_000µs slack
        assert!((m - 0.0019).abs() < 1e-9, "m {m}");
    }

    #[test]
    fn lower_slack_scales_out_sooner() {
        // same queuing delay, different slack → Fig 10 behaviour
        let reports = [rep(0, 4, 20_000.0, true)];
        let (_m_low, d_low) = evaluate(&reports, 50 * MS, 0.3, 0.05);
        let (_m_high, d_high) = evaluate(&reports, 200 * MS, 0.3, 0.05);
        assert_eq!(d_low, ScaleDecision::Out); // 20ms/50ms = 0.4 > 0.3
        assert_eq!(d_high, ScaleDecision::Hold); // 20ms/200ms = 0.1
    }

    #[test]
    fn scale_in_when_idle() {
        let reports = [rep(0, 4, 100.0, true), rep(1, 4, 50.0, true)];
        let (m, d) = evaluate(&reports, 100 * MS, 0.3, 0.05);
        assert!(m < 0.05);
        assert_eq!(d, ScaleDecision::In);
    }

    #[test]
    fn hold_between_thresholds_prevents_oscillation() {
        // metric between SIT and SOT → Hold
        let reports = [rep(0, 1, 10_000.0, true)];
        let (m, d) = evaluate(&reports, 100 * MS, 0.3, 0.05);
        assert!(m > 0.05 && m < 0.3, "m {m}");
        assert_eq!(d, ScaleDecision::Hold);
    }

    #[test]
    fn unfilled_window_gates_decision() {
        let reports = [rep(0, 1, 1e9, false)]; // huge delay but window open
        let (_, d) = evaluate(&reports, 100 * MS, 0.3, 0.05);
        assert_eq!(d, ScaleDecision::Hold);
        // any one unfilled window gates the whole decision
        let reports = [rep(0, 1, 1e9, true), rep(1, 1, 1e9, false)];
        let (_, d) = evaluate(&reports, 100 * MS, 0.3, 0.05);
        assert_eq!(d, ScaleDecision::Hold);
    }

    #[test]
    fn zero_slack_guard() {
        let reports = [rep(0, 1, 500.0, true)];
        let m = scaling_metric(&reports, 0);
        assert!(m.is_finite());
        assert!((m - 0.5).abs() < 1e-9); // normalized by the 1ms floor
    }

    #[test]
    fn empty_reports_zero_metric() {
        assert_eq!(scaling_metric(&[], 100 * MS), 0.0);
    }

    #[test]
    fn zero_sandbox_sgs_still_counts_via_floor() {
        // a just-added SGS with no sandboxes yet shouldn't divide by zero
        let reports = [rep(0, 0, 5_000.0, true)];
        let m = scaling_metric(&reports, 100 * MS);
        assert!((m - 0.05).abs() < 1e-9);
    }
}
