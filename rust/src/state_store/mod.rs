//! External reliable state store + fault-tolerance support (§6, §6.1).
//!
//! The paper keeps SGS state (proactive sandbox counts, estimation
//! state) and LB state (per-DAG SGS mappings) in a reliable external
//! store so a replacement instance can recover and continue. This module
//! provides that store as a versioned key→JSON map with optional file
//! persistence, plus the fail-stop failure detector used by the fault
//! injection hooks.
//!
//! The store is deliberately simple (single-writer-per-key, last-write-
//! wins with version check) — the paper assumes a reliable store rather
//! than contributing one; what matters for reproduction is that recovery
//! round-trips the exact state the services checkpoint.

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Mutex};

use crate::config::Micros;
use crate::util::json::{self, Json};

/// A versioned entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Entry {
    pub version: u64,
    pub value: Json,
}

#[derive(Debug, PartialEq)]
pub enum StoreError {
    VersionConflict {
        key: String,
        expected: u64,
        found: u64,
    },
    Corrupt(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::VersionConflict {
                key,
                expected,
                found,
            } => write!(
                f,
                "version conflict on '{key}': expected {expected}, found {found}"
            ),
            StoreError::Corrupt(m) => write!(f, "corrupt store file: {m}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// The reliable external store. Cheap to clone (shared handle) so every
/// service holds one, as in the paper's deployment.
#[derive(Debug, Clone, Default)]
pub struct StateStore {
    inner: Arc<Mutex<BTreeMap<String, Entry>>>,
}

impl StateStore {
    pub fn new() -> Self {
        StateStore::default()
    }

    /// Unconditional write; returns the new version.
    pub fn put(&self, key: &str, value: Json) -> u64 {
        let mut map = self.inner.lock().unwrap();
        let e = map.entry(key.to_string()).or_insert(Entry {
            version: 0,
            value: Json::Null,
        });
        e.version += 1;
        e.value = value;
        e.version
    }

    /// Compare-and-swap on version (0 = create-only).
    pub fn cas(&self, key: &str, expected: u64, value: Json) -> Result<u64, StoreError> {
        let mut map = self.inner.lock().unwrap();
        let found = map.get(key).map(|e| e.version).unwrap_or(0);
        if found != expected {
            return Err(StoreError::VersionConflict {
                key: key.to_string(),
                expected,
                found,
            });
        }
        let e = map.entry(key.to_string()).or_insert(Entry {
            version: 0,
            value: Json::Null,
        });
        e.version += 1;
        e.value = value;
        Ok(e.version)
    }

    pub fn get(&self, key: &str) -> Option<Entry> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    pub fn delete(&self, key: &str) -> bool {
        self.inner.lock().unwrap().remove(key).is_some()
    }

    /// All keys with a prefix (e.g. `"sgs/3/"` for one SGS's state).
    pub fn list(&self, prefix: &str) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect()
    }

    /// Serialize the full store (checkpoint file).
    pub fn snapshot(&self) -> Json {
        let map = self.inner.lock().unwrap();
        Json::Obj(
            map.iter()
                .map(|(k, e)| {
                    (
                        k.clone(),
                        json::obj(vec![
                            ("version", Json::Int(e.version as i64)),
                            ("value", e.value.clone()),
                        ]),
                    )
                })
                .collect(),
        )
    }

    /// Restore from a checkpoint produced by [`snapshot`](Self::snapshot).
    pub fn restore(snapshot: &Json) -> Result<StateStore, StoreError> {
        let obj = snapshot
            .as_obj()
            .ok_or_else(|| StoreError::Corrupt("snapshot must be an object".into()))?;
        let mut map = BTreeMap::new();
        for (k, v) in obj {
            let version = v
                .req_u64("version")
                .map_err(StoreError::Corrupt)?;
            let value = v.req("value").map_err(StoreError::Corrupt)?.clone();
            map.insert(k.clone(), Entry { version, value });
        }
        Ok(StateStore {
            inner: Arc::new(Mutex::new(map)),
        })
    }

    pub fn save_to_file(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.snapshot().to_pretty())
    }

    pub fn load_from_file(path: &std::path::Path) -> Result<StateStore, StoreError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| StoreError::Corrupt(e.to_string()))?;
        let v = json::parse(&text).map_err(|e| StoreError::Corrupt(e.to_string()))?;
        StateStore::restore(&v)
    }
}

/// Fail-stop failure detector (§6.1 assumes failures are detected
/// immediately). Services heartbeat; anything silent longer than the
/// detection timeout is reported failed.
#[derive(Debug)]
pub struct FailureDetector {
    timeout: Micros,
    last_beat: HashMap<String, Micros>,
}

impl FailureDetector {
    pub fn new(timeout: Micros) -> Self {
        FailureDetector {
            timeout,
            last_beat: HashMap::new(),
        }
    }

    pub fn heartbeat(&mut self, id: &str, now: Micros) {
        self.last_beat.insert(id.to_string(), now);
    }

    /// Services considered failed at `now`.
    pub fn failed(&self, now: Micros) -> Vec<String> {
        let mut out: Vec<String> = self
            .last_beat
            .iter()
            .filter(|(_, &t)| now.saturating_sub(t) > self.timeout)
            .map(|(k, _)| k.clone())
            .collect();
        out.sort();
        out
    }

    pub fn forget(&mut self, id: &str) {
        self.last_beat.remove(id);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MS, SEC};

    #[test]
    fn put_get_versions() {
        let s = StateStore::new();
        assert_eq!(s.put("a", Json::Int(1)), 1);
        assert_eq!(s.put("a", Json::Int(2)), 2);
        let e = s.get("a").unwrap();
        assert_eq!(e.version, 2);
        assert_eq!(e.value, Json::Int(2));
        assert!(s.get("missing").is_none());
    }

    #[test]
    fn cas_conflict_detection() {
        let s = StateStore::new();
        assert_eq!(s.cas("k", 0, Json::Bool(true)).unwrap(), 1);
        assert_eq!(s.cas("k", 1, Json::Bool(false)).unwrap(), 2);
        let err = s.cas("k", 1, Json::Null).unwrap_err();
        assert_eq!(
            err,
            StoreError::VersionConflict {
                key: "k".into(),
                expected: 1,
                found: 2
            }
        );
    }

    #[test]
    fn shared_handle_sees_writes() {
        let a = StateStore::new();
        let b = a.clone();
        a.put("x", Json::Str("y".into()));
        assert_eq!(b.get("x").unwrap().value.as_str(), Some("y"));
    }

    #[test]
    fn prefix_listing() {
        let s = StateStore::new();
        s.put("sgs/0/estimates", Json::Int(1));
        s.put("sgs/0/sandboxes", Json::Int(2));
        s.put("sgs/1/estimates", Json::Int(3));
        s.put("lbs/mapping", Json::Int(4));
        let keys = s.list("sgs/0/");
        assert_eq!(keys, vec!["sgs/0/estimates", "sgs/0/sandboxes"]);
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let s = StateStore::new();
        s.put("a", Json::Int(1));
        s.put("b", json::obj(vec![("nested", Json::Bool(true))]));
        s.put("a", Json::Int(5)); // version 2
        let snap = s.snapshot();
        let r = StateStore::restore(&snap).unwrap();
        assert_eq!(r.get("a").unwrap().version, 2);
        assert_eq!(r.get("a").unwrap().value, Json::Int(5));
        assert_eq!(
            r.get("b").unwrap().value.get("nested"),
            Some(&Json::Bool(true))
        );
    }

    #[test]
    fn file_persistence() {
        let dir = std::env::temp_dir().join("archipelago_store_test");
        let path = dir.join("store.json");
        let s = StateStore::new();
        s.put("dag/0/sgs_list", Json::Arr(vec![Json::Int(0), Json::Int(3)]));
        s.save_to_file(&path).unwrap();
        let r = StateStore::load_from_file(&path).unwrap();
        assert_eq!(
            r.get("dag/0/sgs_list").unwrap().value,
            Json::Arr(vec![Json::Int(0), Json::Int(3)])
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_garbage() {
        assert!(StateStore::restore(&Json::Int(3)).is_err());
        let bad = json::parse(r#"{"k": {"version": "x", "value": 1}}"#).unwrap();
        assert!(StateStore::restore(&bad).is_err());
    }

    #[test]
    fn failure_detector_flags_silent_services() {
        let mut fd = FailureDetector::new(500 * MS);
        fd.heartbeat("sgs-0", 0);
        fd.heartbeat("sgs-1", 0);
        assert!(fd.failed(100 * MS).is_empty());
        fd.heartbeat("sgs-0", 600 * MS);
        let failed = fd.failed(SEC);
        assert_eq!(failed, vec!["sgs-1"]);
        fd.forget("sgs-1");
        assert!(fd.failed(SEC).is_empty());
    }
}
