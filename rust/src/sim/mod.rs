//! Discrete-event simulation engine.
//!
//! The paper evaluated on a 74-machine CloudLab testbed; this build's
//! substitute is a deterministic discrete-event simulator driving the
//! *same* coordinator logic (see DESIGN.md §4). The engine is a classic
//! calendar: a binary heap of `(time, seq, event)` with a strictly
//! monotone sequence number so same-timestamp events dispatch in
//! insertion order (determinism), plus a virtual clock.
//!
//! The event payload is generic; the platform instantiates it with its
//! own event enum. The engine is deliberately unaware of what events
//! mean — `run_until` pops and hands them to a handler closure which may
//! push more events.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::Micros;

/// A scheduled event: fires at `at`, dispatched in push order among
/// equal timestamps.
#[derive(Debug)]
struct Scheduled<E> {
    at: Micros,
    seq: u64,
    event: E,
}

impl<E> PartialEq for Scheduled<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Scheduled<E> {}
impl<E> PartialOrd for Scheduled<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Scheduled<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// The event calendar + virtual clock.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Scheduled<E>>>,
    now: Micros,
    seq: u64,
    dispatched: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
            dispatched: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> Micros {
        self.now
    }

    /// Total events dispatched so far (perf metric).
    pub fn dispatched(&self) -> u64 {
        self.dispatched
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at`; events in the past fire
    /// "now" (clamped), which keeps handlers simple.
    pub fn push_at(&mut self, at: Micros, event: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Scheduled { at, seq, event }));
    }

    /// Schedule `event` after a delay from the current virtual time.
    pub fn push_after(&mut self, delay: Micros, event: E) {
        self.push_at(self.now.saturating_add(delay), event);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Micros, E)> {
        let Reverse(s) = self.heap.pop()?;
        debug_assert!(s.at >= self.now, "time went backwards");
        self.now = s.at;
        self.dispatched += 1;
        Some((s.at, s.event))
    }

    /// Peek at the next event time without dispatching.
    pub fn peek_time(&self) -> Option<Micros> {
        self.heap.peek().map(|Reverse(s)| s.at)
    }
}

/// Drive a handler until the horizon (exclusive) or queue exhaustion.
/// The handler gets `(queue, event)` and may push more events.
pub fn run_until<E, S>(
    queue: &mut EventQueue<E>,
    state: &mut S,
    horizon: Micros,
    mut handler: impl FnMut(&mut EventQueue<E>, &mut S, E),
) {
    while let Some(at) = queue.peek_time() {
        if at >= horizon {
            break;
        }
        let (_, ev) = queue.pop().expect("peeked");
        handler(queue, state, ev);
    }
    // advance the clock to the horizon even if idle
    if queue.now < horizon {
        queue.now = horizon;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_among_equal_timestamps() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push_at(10, 1);
        q.push_at(10, 2);
        q.push_at(5, 0);
        q.push_at(10, 3);
        let order: Vec<u32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q: EventQueue<&str> = EventQueue::new();
        q.push_at(100, "a");
        q.push_at(50, "b");
        assert_eq!(q.now(), 0);
        let (t1, e1) = q.pop().unwrap();
        assert_eq!((t1, e1, q.now()), (50, "b", 50));
        let (t2, _) = q.pop().unwrap();
        assert_eq!((t2, q.now()), (100, 100));
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.push_at(100, 1);
        q.pop();
        q.push_at(10, 2); // in the past
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 100);
    }

    #[test]
    fn push_after_uses_virtual_now() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.push_at(1000, 1);
        q.pop();
        q.push_after(50, 2);
        assert_eq!(q.peek_time(), Some(1050));
    }

    #[test]
    fn run_until_respects_horizon() {
        let mut q: EventQueue<u64> = EventQueue::new();
        for t in [10u64, 20, 30, 40, 50] {
            q.push_at(t, t);
        }
        let mut seen = Vec::new();
        run_until(&mut q, &mut seen, 35, |_q, seen, e| seen.push(e));
        assert_eq!(seen, vec![10, 20, 30]);
        assert_eq!(q.now(), 35);
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn handler_can_push_cascading_events() {
        let mut q: EventQueue<u32> = EventQueue::new();
        q.push_at(0, 0);
        let mut count = 0u32;
        run_until(&mut q, &mut count, 1_000, |q, count, depth| {
            *count += 1;
            if depth < 9 {
                q.push_after(10, depth + 1);
            }
        });
        assert_eq!(count, 10);
        assert_eq!(q.dispatched(), 10);
    }
}
