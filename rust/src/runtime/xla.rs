//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The real-execution path ([`crate::runtime`], [`crate::platform::realtime`])
//! is written against the API surface of the `xla` crate (PJRT CPU client +
//! HLO-text module loading). That crate links a native `xla_extension`
//! build and is not available in this offline environment, so this module
//! provides the same surface with constructors that fail cleanly at
//! runtime: manifest parsing and everything simulation-side works, while
//! attempting to actually compile or execute an artifact reports an
//! explanatory error instead of failing to build. Swapping in the real
//! bindings means replacing this module with the external crate — no
//! caller changes.

use std::fmt;

/// Error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str = "xla backend not available in this build (offline stub); \
     simulation mode is unaffected — link the real `xla` crate for PJRT execution";

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(UNAVAILABLE.to_string()))
}

/// XLA primitive types the runtime can receive as outputs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// A host-side literal (input or output tensor).
pub struct Literal;

impl Literal {
    pub fn vec1<T>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        unavailable()
    }

    pub fn element_type(&self) -> Result<ElementType, Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

/// A parsed HLO module (loaded from `artifacts/*.hlo.txt`).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// A computation ready for PJRT compilation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device buffer returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// The PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_cleanly_not_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("offline stub"));
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[0f32]).reshape(&[1]).is_err());
    }
}
