//! PJRT runtime: load and execute the AOT-compiled function bodies.
//!
//! The build path is: Pallas kernels (L1) → JAX models (L2) →
//! `python/compile/aot.py` → `artifacts/*.hlo.txt` + `manifest.json`.
//! This module is the request-path half: it parses the manifest, loads
//! each HLO-text module, compiles it once on the PJRT CPU client, and
//! executes it with concrete inputs — no Python anywhere.
//!
//! HLO *text* is the interchange format (not serialized protos): jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).

pub mod xla;

use std::collections::HashMap;
use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One compiled artifact's metadata (a row of `manifest.json`).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub model: String,
    pub batch: usize,
    pub file: String,
    pub input_shape: Vec<usize>,
    /// "f32" or "i32".
    pub input_dtype: String,
    pub output_shapes: Vec<Vec<usize>>,
    pub flops: u64,
    /// Structural L1 perf estimates (DESIGN.md §Perf).
    pub vmem_bytes: u64,
    pub mxu_utilization: f64,
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub weight_seed: u64,
    pub entries: Vec<ArtifactEntry>,
}

#[derive(Debug)]
pub enum RuntimeError {
    Manifest(String),
    UnknownArtifact(String),
    InputShape {
        name: String,
        expected: usize,
        got: usize,
    },
    Xla(String),
    Io(std::io::Error),
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Manifest(m) => write!(f, "manifest: {m}"),
            RuntimeError::UnknownArtifact(n) => write!(f, "unknown artifact '{n}'"),
            RuntimeError::InputShape {
                name,
                expected,
                got,
            } => write!(
                f,
                "input shape mismatch for '{name}': expected {expected} elements, got {got}"
            ),
            RuntimeError::Xla(m) => write!(f, "xla: {m}"),
            RuntimeError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for RuntimeError {
    fn from(e: std::io::Error) -> Self {
        RuntimeError::Io(e)
    }
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}

impl Manifest {
    /// An artifact-less manifest — used by servers running on the stub
    /// executor, where no compiled artifacts exist.
    pub fn empty() -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            weight_seed: 0,
            entries: Vec::new(),
        }
    }

    /// Parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, RuntimeError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let v = json::parse(&text).map_err(|e| RuntimeError::Manifest(e.to_string()))?;
        Self::from_json(dir, &v)
    }

    pub fn from_json(dir: &Path, v: &Json) -> Result<Manifest, RuntimeError> {
        let merr = RuntimeError::Manifest;
        let entries_json = v
            .req("entries")
            .map_err(merr)?
            .as_arr()
            .ok_or_else(|| RuntimeError::Manifest("'entries' must be an array".into()))?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let shape = |key: &str| -> Result<Vec<usize>, RuntimeError> {
                e.req(key)
                    .map_err(merr)?
                    .as_arr()
                    .ok_or_else(|| RuntimeError::Manifest(format!("'{key}' must be an array")))?
                    .iter()
                    .map(|d| {
                        d.as_u64()
                            .map(|x| x as usize)
                            .ok_or_else(|| RuntimeError::Manifest(format!("bad dim in {key}")))
                    })
                    .collect()
            };
            let output_shapes = e
                .req("output_shapes")
                .map_err(merr)?
                .as_arr()
                .ok_or_else(|| RuntimeError::Manifest("'output_shapes' must be an array".into()))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .ok_or_else(|| RuntimeError::Manifest("bad output shape".into()))?
                        .iter()
                        .map(|d| {
                            d.as_u64()
                                .map(|x| x as usize)
                                .ok_or_else(|| RuntimeError::Manifest("bad output dim".into()))
                        })
                        .collect()
                })
                .collect::<Result<Vec<Vec<usize>>, _>>()?;
            entries.push(ArtifactEntry {
                name: e.req_str("name").map_err(merr)?.to_string(),
                model: e.req_str("model").map_err(merr)?.to_string(),
                batch: e.req_u64("batch").map_err(merr)? as usize,
                file: e.req_str("file").map_err(merr)?.to_string(),
                input_shape: shape("input_shape")?,
                input_dtype: e.req_str("input_dtype").map_err(merr)?.to_string(),
                output_shapes,
                flops: e.req_u64("flops").map_err(merr)?,
                vmem_bytes: e.req_u64("vmem_bytes").unwrap_or(0),
                mxu_utilization: e.req_f64("mxu_utilization").unwrap_or(0.0),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            weight_seed: v.req_u64("weight_seed").unwrap_or(0),
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Best artifact of `model` covering a batch of `n` (smallest batch
    /// ≥ n, else the largest available) — the dynamic batcher's lookup.
    pub fn pick_batch(&self, model: &str, n: usize) -> Option<&ArtifactEntry> {
        let mut of_model: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.model == model).collect();
        of_model.sort_by_key(|e| e.batch);
        of_model
            .iter()
            .find(|e| e.batch >= n)
            .copied()
            .or_else(|| of_model.last().copied())
    }
}

/// Output of one artifact execution.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl Tensor {
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
            Tensor::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Typed input accepted by [`Runtime::execute`].
#[derive(Debug, Clone)]
pub enum Input<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

impl Input<'_> {
    fn len(&self) -> usize {
        match self {
            Input::F32(v) => v.len(),
            Input::I32(v) => v.len(),
        }
    }
}

struct Loaded {
    entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT runtime: one CPU client + compiled executables by name.
pub struct Runtime {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over the artifacts in `dir`, compiling
    /// every manifest entry (one executable per model×batch variant).
    pub fn load_dir(dir: &Path) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut rt = Runtime {
            client,
            loaded: HashMap::new(),
            manifest: manifest.clone(),
        };
        for entry in &manifest.entries {
            rt.load_entry(entry)?;
        }
        Ok(rt)
    }

    /// Create a runtime compiling only the named artifacts (faster start).
    pub fn load_subset(dir: &Path, names: &[&str]) -> Result<Runtime, RuntimeError> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut rt = Runtime {
            client,
            loaded: HashMap::new(),
            manifest: manifest.clone(),
        };
        for name in names {
            let entry = manifest
                .entry(name)
                .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?
                .clone();
            rt.load_entry(&entry)?;
        }
        Ok(rt)
    }

    fn load_entry(&mut self, entry: &ArtifactEntry) -> Result<(), RuntimeError> {
        let path = self.manifest.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.loaded.insert(
            entry.name.clone(),
            Loaded {
                entry: entry.clone(),
                exe,
            },
        );
        Ok(())
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    pub fn loaded_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.loaded.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    /// Execute artifact `name` with a flat input buffer (row-major over
    /// the manifest's input shape). Returns the tuple of outputs.
    pub fn execute(&self, name: &str, input: Input<'_>) -> Result<Vec<Tensor>, RuntimeError> {
        let loaded = self
            .loaded
            .get(name)
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))?;
        let expected: usize = loaded.entry.input_shape.iter().product();
        if input.len() != expected {
            return Err(RuntimeError::InputShape {
                name: name.to_string(),
                expected,
                got: input.len(),
            });
        }
        let dims: Vec<i64> = loaded.entry.input_shape.iter().map(|&d| d as i64).collect();
        let literal = match input {
            Input::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            Input::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        let result = loaded.exe.execute::<xla::Literal>(&[literal])?[0][0]
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: the root is always a tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            let ty = p.element_type()?;
            out.push(match ty {
                xla::ElementType::F32 => Tensor::F32(p.to_vec::<f32>()?),
                xla::ElementType::S32 => Tensor::I32(p.to_vec::<i32>()?),
                xla::ElementType::S64 => Tensor::I64(p.to_vec::<i64>()?),
                other => {
                    return Err(RuntimeError::Xla(format!(
                        "unsupported output element type {other:?}"
                    )))
                }
            });
        }
        Ok(out)
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.loaded.get(name).map(|l| &l.entry)
    }
}

// ---------------------------------------------------------------------
// Executor boundary (DESIGN.md §Coordinator): the seam between the
// scheduling plane and actual computation. The real-time driver
// dispatches through these traits, so the same coordinator core can run
// against PJRT-compiled artifacts or a test stub with no artifacts.
// ---------------------------------------------------------------------

/// One worker thread's execution backend. Created on the worker's own
/// thread (PJRT handles are not `Send`; per-thread executors mirror the
/// paper's per-machine sandboxes: an executable compiled on worker A
/// cannot serve worker B).
pub trait WorkerExecutor {
    /// Cold start: make `artifact` warm here (e.g. HLO parse + compile).
    fn warm_up(&mut self, artifact: &str) -> Result<(), RuntimeError>;

    /// Whether `artifact` is already warm on this worker.
    fn is_warm(&self, artifact: &str) -> bool;

    /// Run `artifact` on `input`. Implementations warm up on demand if
    /// the artifact is not yet warm (the cost lands on this call).
    fn execute(&mut self, artifact: &str, input: &[f32]) -> Result<Vec<Tensor>, RuntimeError>;
}

/// Builds one [`WorkerExecutor`] per worker thread. Shared across the
/// real-time server's threads, hence `Send + Sync`.
pub trait ExecutorFactory: Send + Sync {
    fn make(&self, worker: usize) -> Result<Box<dyn WorkerExecutor>, RuntimeError>;
}

/// PJRT-backed executor: per-worker CPU client + executable cache.
pub struct XlaExecutor {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    dir: PathBuf,
    manifest: Manifest,
}

impl XlaExecutor {
    pub fn new(dir: PathBuf, manifest: Manifest) -> Result<Self, RuntimeError> {
        Ok(XlaExecutor {
            client: xla::PjRtClient::cpu()?,
            cache: HashMap::new(),
            dir,
            manifest,
        })
    }
}

impl WorkerExecutor for XlaExecutor {
    fn warm_up(&mut self, artifact: &str) -> Result<(), RuntimeError> {
        if self.cache.contains_key(artifact) {
            return Ok(());
        }
        let entry = self
            .manifest
            .entry(artifact)
            .ok_or_else(|| RuntimeError::UnknownArtifact(artifact.to_string()))?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| RuntimeError::Manifest("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(artifact.to_string(), exe);
        Ok(())
    }

    fn is_warm(&self, artifact: &str) -> bool {
        self.cache.contains_key(artifact)
    }

    fn execute(&mut self, artifact: &str, input: &[f32]) -> Result<Vec<Tensor>, RuntimeError> {
        self.warm_up(artifact)?;
        let entry = self
            .manifest
            .entry(artifact)
            .ok_or_else(|| RuntimeError::UnknownArtifact(artifact.to_string()))?;
        let dims: Vec<i64> = entry.input_shape.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input).reshape(&dims)?;
        let exe = self.cache.get(artifact).expect("warmed above");
        let result = exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(match p.element_type()? {
                xla::ElementType::F32 => Tensor::F32(p.to_vec::<f32>()?),
                xla::ElementType::S32 => Tensor::I32(p.to_vec::<i32>()?),
                xla::ElementType::S64 => Tensor::I64(p.to_vec::<i64>()?),
                other => return Err(RuntimeError::Xla(format!("output type {other:?}"))),
            });
        }
        Ok(out)
    }
}

/// Factory for [`XlaExecutor`]s over one artifact directory.
pub struct XlaExecutorFactory {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl ExecutorFactory for XlaExecutorFactory {
    fn make(&self, _worker: usize) -> Result<Box<dyn WorkerExecutor>, RuntimeError> {
        Ok(Box::new(XlaExecutor::new(
            self.dir.clone(),
            self.manifest.clone(),
        )?))
    }
}

/// Deterministic stand-in executor: no artifacts, no PJRT. `warm_up`
/// sleeps the artifact's setup cost (the real compile's stand-in),
/// `execute` sleeps its exec cost and returns `[sum(input)]` so callers
/// can verify data flow. Costs come from the factory's per-artifact
/// table when present, the flat defaults otherwise — the table is what
/// lets an open-loop replay reproduce a workload's real service-time
/// distribution on the stub. Drives the real-time platform in tests and
/// demos.
pub struct StubExecutor {
    warm: std::collections::HashSet<String>,
    setup_cost: std::time::Duration,
    exec_cost: std::time::Duration,
    costs: HashMap<String, (std::time::Duration, std::time::Duration)>,
    fail_artifacts: std::collections::HashSet<String>,
}

impl StubExecutor {
    fn cost_of(&self, artifact: &str) -> (std::time::Duration, std::time::Duration) {
        self.costs
            .get(artifact)
            .copied()
            .unwrap_or((self.setup_cost, self.exec_cost))
    }
}

impl WorkerExecutor for StubExecutor {
    fn warm_up(&mut self, artifact: &str) -> Result<(), RuntimeError> {
        let (setup, _) = self.cost_of(artifact);
        if self.warm.insert(artifact.to_string()) && !setup.is_zero() {
            std::thread::sleep(setup);
        }
        Ok(())
    }

    fn is_warm(&self, artifact: &str) -> bool {
        self.warm.contains(artifact)
    }

    fn execute(&mut self, artifact: &str, input: &[f32]) -> Result<Vec<Tensor>, RuntimeError> {
        self.warm_up(artifact)?;
        let (_, exec) = self.cost_of(artifact);
        if !exec.is_zero() {
            std::thread::sleep(exec);
        }
        if self.fail_artifacts.contains(artifact) {
            return Err(RuntimeError::Xla(format!(
                "injected failure for '{artifact}'"
            )));
        }
        Ok(vec![Tensor::F32(vec![input.iter().sum()])])
    }
}

/// Factory for [`StubExecutor`]s.
///
/// `setup_cost`/`exec_cost` are the flat per-operation defaults;
/// `costs` overrides them per artifact name (setup, exec) so workload
/// replays can give every function its sampled service time;
/// `fail_artifacts` makes the named artifacts' executions return an
/// error — the failure-injection hook for testing the explicit
/// failed-completion path.
#[derive(Debug, Clone, Default)]
pub struct StubExecutorFactory {
    pub setup_cost: std::time::Duration,
    pub exec_cost: std::time::Duration,
    pub costs: HashMap<String, (std::time::Duration, std::time::Duration)>,
    pub fail_artifacts: std::collections::HashSet<String>,
}

impl ExecutorFactory for StubExecutorFactory {
    fn make(&self, _worker: usize) -> Result<Box<dyn WorkerExecutor>, RuntimeError> {
        Ok(Box::new(StubExecutor {
            warm: Default::default(),
            setup_cost: self.setup_cost,
            exec_cost: self.exec_cost,
            costs: self.costs.clone(),
            fail_artifacts: self.fail_artifacts.clone(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn stub_executor_tracks_warmth_and_sums_input() {
        let factory = StubExecutorFactory::default();
        let mut exec = factory.make(0).unwrap();
        assert!(!exec.is_warm("f"));
        exec.warm_up("f").unwrap();
        assert!(exec.is_warm("f"));
        let out = exec.execute("f", &[1.0, 2.0, 3.5]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[6.5]);
        assert!(exec.is_warm("f"));
        assert!(!exec.is_warm("g"));
    }

    #[test]
    fn stub_executor_per_artifact_costs_and_injected_failure() {
        let mut factory = StubExecutorFactory::default();
        factory.costs.insert(
            "slow".into(),
            (
                std::time::Duration::ZERO,
                std::time::Duration::from_millis(1),
            ),
        );
        factory.fail_artifacts.insert("boom".into());
        let mut exec = factory.make(0).unwrap();
        assert!(exec.execute("ok", &[1.0]).is_ok());
        let err = exec.execute("boom", &[1.0]).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        assert!(exec.is_warm("boom"), "failure lands after warm-up");
        // injected failures are persistent, not one-shot
        assert!(exec.execute("boom", &[1.0]).is_err());
        let out = exec.execute("slow", &[2.0, 3.0]).unwrap();
        assert_eq!(out[0].as_f32().unwrap(), &[5.0]);
    }

    #[test]
    fn manifest_parses() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 9);
        let e = m.entry("mlp_infer_b1").unwrap();
        assert_eq!(e.input_shape, vec![1, 256]);
        assert_eq!(e.input_dtype, "f32");
        assert_eq!(e.output_shapes[0], vec![1, 10]);
        assert!(e.flops > 0);
    }

    #[test]
    fn pick_batch_selects_covering_variant() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_batch("mlp_infer", 1).unwrap().batch, 1);
        assert_eq!(m.pick_batch("mlp_infer", 3).unwrap().batch, 4);
        assert_eq!(m.pick_batch("mlp_infer", 9).unwrap().batch, 16);
        // beyond the largest: take the largest
        assert_eq!(m.pick_batch("mlp_infer", 99).unwrap().batch, 16);
        assert!(m.pick_batch("nope", 1).is_none());
    }

    #[test]
    fn execute_mlp_infer_probs_sum_to_one() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load_subset(&dir, &["mlp_infer_b4"]).unwrap();
        let input: Vec<f32> = (0..4 * 256).map(|i| (i as f32 * 0.01).sin()).collect();
        let out = rt.execute("mlp_infer_b4", Input::F32(&input)).unwrap();
        assert_eq!(out.len(), 2, "probs + argmax");
        let probs = out[0].as_f32().unwrap();
        assert_eq!(probs.len(), 4 * 10);
        for row in probs.chunks(10) {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
            assert!(row.iter().all(|p| *p >= 0.0));
        }
        // argmax consistent with probs
        match &out[1] {
            Tensor::I32(preds) => {
                for (b, row) in probs.chunks(10).enumerate() {
                    let am = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    assert_eq!(preds[b] as usize, am);
                }
            }
            Tensor::I64(preds) => {
                assert_eq!(preds.len(), 4);
            }
            other => panic!("unexpected argmax type {other:?}"),
        }
    }

    #[test]
    fn execute_is_deterministic() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load_subset(&dir, &["anomaly_score_b1"]).unwrap();
        let input: Vec<f32> = (0..128).map(|i| i as f32 * 0.1).collect();
        let a = rt.execute("anomaly_score_b1", Input::F32(&input)).unwrap();
        let b = rt.execute("anomaly_score_b1", Input::F32(&input)).unwrap();
        assert_eq!(a, b);
        let score = a[0].as_f32().unwrap()[0];
        assert!(score > 0.0 && score < 1.0, "sigmoid range: {score}");
    }

    #[test]
    fn execute_i32_text_featurize() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load_subset(&dir, &["text_featurize_b1"]).unwrap();
        let tokens: Vec<i32> = (0..32).map(|i| i % 128).collect();
        let out = rt.execute("text_featurize_b1", Input::I32(&tokens)).unwrap();
        let feat = out[0].as_f32().unwrap();
        assert_eq!(feat.len(), 64);
        assert!(feat.iter().all(|x| x.abs() <= 1.0), "tanh range");
    }

    #[test]
    fn shape_mismatch_rejected() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let rt = Runtime::load_subset(&dir, &["mlp_infer_b1"]).unwrap();
        let bad = vec![0f32; 7];
        assert!(matches!(
            rt.execute("mlp_infer_b1", Input::F32(&bad)),
            Err(RuntimeError::InputShape { .. })
        ));
        assert!(matches!(
            rt.execute("missing", Input::F32(&bad)),
            Err(RuntimeError::UnknownArtifact(_))
        ));
    }
}
