//! The `archipelago` launcher.
//!
//! Subcommands:
//!
//! * `simulate` — run the simulated platform on a C1–C4 macrobenchmark
//!   mix (or a config file) and print the latency/deadline report.
//! * `baseline` — same workload on a baseline stack (fifo | sparrow).
//! * `figures`  — regenerate the paper's tables/figures (CSV + summary).
//! * `serve`    — real-time serving of the compiled artifacts (PJRT on
//!   the request path); demo load generator included.
//! * `loadtest` — open-loop wall-clock load harness: replay a W1/W2
//!   schedule against the real-time server and report deadline
//!   attainment + tail latencies (the paper's headline quantities).
//! * `validate` — quick self-check: config, artifacts, determinism.

use std::process::ExitCode;

use archipelago::baseline::{BaselineKind, BaselineOptions, BaselineSim};
use archipelago::config::{Config, SchedPolicy, SEC};
use archipelago::experiments::{run_all, run_one, ExpContext};
use archipelago::platform::realtime::Server;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::util::cli::{Args, CliError, Command};
use archipelago::util::logging;
use archipelago::workload::{macro_mix, peak_offered_cores, WorkloadKind};

fn main() -> ExitCode {
    logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(sub) = argv.first().map(|s| s.as_str()) else {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    };
    let rest = &argv[1..];
    let result = match sub {
        "simulate" => cmd_simulate(rest),
        "baseline" => cmd_baseline(rest),
        "figures" => cmd_figures(rest),
        "serve" => cmd_serve(rest),
        "loadtest" => cmd_loadtest(rest),
        "validate" => cmd_validate(rest),
        "--help" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(CliError(format!("unknown subcommand '{other}'\n{}", usage()))),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("{e}");
            ExitCode::FAILURE
        }
    }
}

fn usage() -> String {
    "archipelago — reproduction of 'Archipelago: A Scalable Low-Latency \
     Serverless Platform'\n\nUsage: archipelago <subcommand> [options]\n\n\
     Subcommands:\n\
     \x20 simulate   run the platform on a macrobenchmark mix\n\
     \x20 baseline   run a baseline stack (--kind fifo|sparrow)\n\
     \x20 figures    regenerate paper tables/figures (--all or --id <id>)\n\
     \x20 serve      real-time PJRT serving demo (needs `make artifacts`)\n\
     \x20 loadtest   open-loop wall-clock load harness (--stub)\n\
     \x20 validate   config + artifact + determinism self-check\n\n\
     Run `archipelago <subcommand> --help` for options."
        .into()
}

fn parse_workload(args: &Args) -> Result<WorkloadKind, CliError> {
    match args.get_or("workload", "w2") {
        "w1" => Ok(WorkloadKind::W1),
        "w2" => Ok(WorkloadKind::W2),
        other => Err(CliError(format!("--workload must be w1|w2, got '{other}'"))),
    }
}

fn load_config(args: &Args) -> Result<Config, CliError> {
    match args.get("config") {
        Some(path) => Config::from_file(path).map_err(|e| CliError(e.to_string())),
        None => Ok(Config::default()),
    }
}

fn scaled_mix(kind: WorkloadKind, cfg: &Config, seed: u64, dags_per_class: u64) -> Vec<archipelago::workload::App> {
    let probe = macro_mix(kind, dags_per_class as usize, 1.0, seed);
    let peak: f64 = probe.iter().map(peak_offered_cores).sum();
    let scale = cfg.total_cores() as f64 / peak;
    macro_mix(kind, dags_per_class as usize, scale, seed)
}

fn cmd_simulate(raw: &[String]) -> Result<(), CliError> {
    let cmd = Command::new("simulate", "run the simulated Archipelago platform")
        .opt("config", "platform config JSON (default: paper testbed)")
        .opt("workload", "w1 | w2 (default w2)")
        .opt("seed", "rng seed (default 42)")
        .opt("duration", "virtual seconds (default 120)")
        .opt("warmup", "warmup seconds excluded from metrics (default 30)")
        .opt("dags-per-class", "DAGs per class C1-C4 (default 2)");
    let args = cmd.parse(raw)?;
    let cfg = load_config(&args)?;
    let kind = parse_workload(&args)?;
    let seed = args.get_u64("seed", 42)?;
    let duration = args.get_u64("duration", 120)?;
    let warmup = args.get_u64("warmup", 30)?;
    let dpc = args.get_u64("dags-per-class", 2)?;
    let apps = scaled_mix(kind, &cfg, seed, dpc);
    println!(
        "simulating {:?} with {} DAGs on {} SGS x {} workers x {} cores for {duration}s",
        kind,
        apps.len(),
        cfg.cluster.num_sgs,
        cfg.cluster.workers_per_sgs,
        cfg.cluster.cores_per_worker
    );
    let opts = SimOptions {
        seed,
        horizon: duration * SEC,
        warmup: warmup * SEC,
        ..SimOptions::default()
    };
    let mut p = SimPlatform::new(cfg, apps, opts);
    let row = p.run();
    println!("{}", row.format_line("archipelago"));
    println!(
        "cold starts: {} | scale-outs: {} | scale-ins: {} | events: {}",
        p.total_cold_starts(),
        p.lbs().scale_outs(),
        p.lbs().scale_ins(),
        p.events_dispatched()
    );
    Ok(())
}

fn cmd_baseline(raw: &[String]) -> Result<(), CliError> {
    let cmd = Command::new("baseline", "run a baseline serving stack")
        .opt("kind", "fifo | sparrow (default fifo)")
        .opt("workload", "w1 | w2 (default w2)")
        .opt("seed", "rng seed (default 42)")
        .opt("duration", "virtual seconds (default 120)")
        .opt("pool-mb", "per-worker container pool MB (default 8192)");
    let args = cmd.parse(raw)?;
    let kind = match args.get_or("kind", "fifo") {
        "fifo" => BaselineKind::CentralizedFifo,
        "sparrow" => BaselineKind::Sparrow { probes: 2 },
        other => return Err(CliError(format!("--kind must be fifo|sparrow, got '{other}'"))),
    };
    let cfg = Config::default();
    let wkind = parse_workload(&args)?;
    let seed = args.get_u64("seed", 42)?;
    let duration = args.get_u64("duration", 120)?;
    let pool = args.get_u64("pool-mb", 8192)?;
    let apps = scaled_mix(wkind, &cfg, seed, 2);
    let opts = BaselineOptions {
        kind,
        seed,
        horizon: duration * SEC,
        warmup: duration * SEC / 4,
        decision_cost: 100,
        ..BaselineOptions::default()
    };
    let mut sim = BaselineSim::new(
        cfg.cluster.num_sgs * cfg.cluster.workers_per_sgs,
        cfg.cluster.cores_per_worker,
        pool,
        apps,
        opts,
    );
    let row = sim.run();
    println!("{}", row.format_line(&format!("baseline ({kind:?})")));
    println!("cold starts (total incl. warmup): {}", sim.cold_starts());
    Ok(())
}

fn cmd_figures(raw: &[String]) -> Result<(), CliError> {
    let cmd = Command::new("figures", "regenerate the paper's tables and figures")
        .flag("all", "run every experiment")
        .opt("id", "one experiment id (fig1|fig2abc|fig2d|table1|fig7|fig8|fig9|lru|fig10|fig11|gradual|fig12|fig13)")
        .opt("out-dir", "output directory for CSVs (default results)")
        .opt("seed", "rng seed (default 42)")
        .flag("quick", "reduced horizons (CI/bench mode)");
    let args = cmd.parse(raw)?;
    let mut ctx = ExpContext::new(args.get_or("out-dir", "results"));
    ctx.quick = args.has("quick");
    ctx.seed = args.get_u64("seed", 42)?;
    std::fs::create_dir_all(&ctx.out_dir).map_err(|e| CliError(e.to_string()))?;
    let results = if args.has("all") {
        run_all(&ctx)
    } else if let Some(id) = args.get("id") {
        vec![run_one(id, &ctx)
            .ok_or_else(|| CliError(format!("unknown experiment id '{id}'")))?]
    } else {
        return Err(CliError("pass --all or --id <id>".into()));
    };
    let mut report = String::new();
    for r in &results {
        let block = r.render();
        println!("{block}");
        report.push_str(&block);
        report.push('\n');
    }
    let report_path = ctx.out_dir.join("summary.txt");
    std::fs::write(&report_path, &report).map_err(|e| CliError(e.to_string()))?;
    println!("summary written to {}", report_path.display());
    Ok(())
}

fn cmd_serve(raw: &[String]) -> Result<(), CliError> {
    let cmd = Command::new("serve", "real-time serving demo (PJRT or stub executor)")
        .opt("artifacts", "artifact directory (default artifacts)")
        .opt("workers", "worker threads per SGS shard (default 2)")
        .opt("sgs", "coordinator shards, one lock each; --stub mode (default 2)")
        .opt("requests", "demo requests to push (default 200)")
        .opt("policy", "srsf | fifo (default srsf)")
        .flag(
            "stub",
            "serve demo DAGs on the stub executor (no artifacts or xla needed)",
        );
    let args = cmd.parse(raw)?;
    let workers = args.get_u64("workers", 2)? as usize;
    let num_sgs = args.get_u64("sgs", 2)? as usize;
    let n = args.get_u64("requests", 200)?;
    let policy = match args.get_or("policy", "srsf") {
        "srsf" => SchedPolicy::Srsf,
        "fifo" => SchedPolicy::Fifo,
        other => return Err(CliError(format!("--policy must be srsf|fifo, got '{other}'"))),
    };
    if args.has("stub") {
        return serve_stub_demo(workers, num_sgs, n, policy);
    }
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    if !dir.join("manifest.json").exists() {
        return Err(CliError(format!(
            "no manifest in {} — run `make artifacts` first, or pass --stub",
            dir.display()
        )));
    }
    println!("starting server: {workers} workers, {policy:?}");
    let server = Server::start(&dir, workers, policy, &["mlp_infer_b1"])
        .map_err(|e| CliError(e.to_string()))?;
    let mut lat = archipelago::util::stats::Summary::new();
    let mut colds = 0u64;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let input: Vec<f32> = (0..256).map(|j| ((i + j) as f32 * 0.01).sin()).collect();
        let rx = server.submit("mlp_infer_b1", input, 100_000);
        let c = rx.recv().map_err(|e| CliError(e.to_string()))?;
        lat.record(c.e2e_us as f64);
        colds += u64::from(c.cold);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n} requests: p50={:.0}us p99={:.0}us | {:.0} req/s | colds={colds}",
        lat.quantile(0.5),
        lat.quantile(0.99),
        n as f64 / wall
    );
    server.shutdown();
    Ok(())
}

/// `serve --stub`: the wall-clock platform end-to-end — single-function
/// and 3-stage DAG requests through the sharded coordinator (`num_sgs`
/// shards, one lock each) — with the stub executor standing in for
/// PJRT.
fn serve_stub_demo(
    workers: usize,
    num_sgs: usize,
    n: u64,
    policy: SchedPolicy,
) -> Result<(), CliError> {
    use archipelago::config::MS;
    use archipelago::dag::{DagId, DagSpec};
    use archipelago::platform::realtime::RtOptions;
    use archipelago::runtime::{Manifest, StubExecutorFactory};
    use std::sync::Arc;
    use std::time::Duration;

    let dags = vec![
        DagSpec::single(DagId(0), "score", 2 * MS, 50 * MS, 128, 200 * MS),
        DagSpec::chain(
            DagId(1),
            "pipeline",
            &[
                (2 * MS, 50 * MS, 128),
                (3 * MS, 50 * MS, 128),
                (2 * MS, 50 * MS, 128),
            ],
            400 * MS,
        ),
    ];
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::from_millis(20),
        exec_cost: Duration::from_millis(2),
        ..Default::default()
    });
    let opts = RtOptions {
        num_sgs,
        workers,
        policy,
        ..RtOptions::default()
    };
    println!(
        "starting stub server: {num_sgs} SGS shards x {workers} workers, {policy:?}, \
         DAGs: score, pipeline(3)"
    );
    let server = Server::start_with(factory, dags, opts, &["score"], Manifest::empty())
        .map_err(|e| CliError(e.to_string()))?;
    let pipeline = server
        .dag_id("pipeline")
        .expect("pipeline DAG registered above");
    let t0 = std::time::Instant::now();
    let mut single_lat = archipelago::util::stats::Summary::new();
    let mut dag_lat = archipelago::util::stats::Summary::new();
    let mut met = 0u64;
    for i in 0..n {
        if i % 4 == 0 {
            let rx = server.submit_dag(pipeline, vec![i as f32, 1.0], 400_000);
            let c = rx.recv().map_err(|e| CliError(e.to_string()))?;
            dag_lat.record(c.e2e_us as f64);
            met += u64::from(c.deadline_met);
            assert_eq!(c.functions.len(), 3, "all three stages must run");
        } else {
            let rx = server.submit("score", vec![i as f32, 2.0], 200_000);
            let c = rx.recv().map_err(|e| CliError(e.to_string()))?;
            single_lat.record(c.e2e_us as f64);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "single-fn : p50={:.0}us p99={:.0}us",
        single_lat.quantile(0.5),
        single_lat.quantile(0.99)
    );
    println!(
        "3-fn DAG  : p50={:.0}us p99={:.0}us | deadlines met {met}/{}",
        dag_lat.quantile(0.5),
        dag_lat.quantile(0.99),
        (n + 3) / 4
    );
    println!(
        "{}",
        server.summary().format_line("realtime (stub)")
    );
    println!(
        "served {n} requests in {wall:.2}s ({:.0} req/s) | cold starts {}",
        n as f64 / wall,
        server.total_cold_starts()
    );
    server.shutdown();
    Ok(())
}

/// `loadtest --stub`: the open-loop serving harness — materialize a
/// W1/W2 schedule, replay it against a fresh stub server, print the
/// deadline-attainment report (the same quantities `benches/e2e.rs`
/// writes to `BENCH_e2e.json`).
fn cmd_loadtest(raw: &[String]) -> Result<(), CliError> {
    use archipelago::loadgen::{self, LoadgenOptions, StubLoadtestConfig};
    use archipelago::util::json::{self, Json};

    let cmd = Command::new(
        "loadtest",
        "open-loop wall-clock load harness (deadline attainment)",
    )
    .flag("stub", "run on the stub executor (required; no artifacts needed)")
    .opt("workload", "w1 | w2 (default w2)")
    .opt("policy", "srsf | fifo | both (default both)")
    .opt("duration", "schedule horizon in virtual seconds (default 15)")
    .opt(
        "time-scale",
        "stretch arrivals/service times/deadlines by this factor (default 1.0)",
    )
    .opt("util", "target mean utilization of the stub cores (default 0.8)")
    .opt("sgs", "coordinator shards (default 2)")
    .opt("workers", "worker threads per shard (default 2)")
    .opt("dags-per-class", "DAGs per class C1-C4 (default 1)")
    .opt("seed", "rng seed (default 42)")
    .opt("out", "also write the run report JSON to this path");
    let args = cmd.parse(raw)?;
    if !args.has("stub") {
        return Err(CliError(
            "loadtest currently supports --stub only (artifact DAGs have no \
             workload-class mapping yet) — pass --stub"
                .into(),
        ));
    }
    let kind = parse_workload(&args)?;
    let policies = match args.get_or("policy", "both") {
        "srsf" => vec![SchedPolicy::Srsf],
        "fifo" => vec![SchedPolicy::Fifo],
        "both" => vec![SchedPolicy::Srsf, SchedPolicy::Fifo],
        other => {
            return Err(CliError(format!(
                "--policy must be srsf|fifo|both, got '{other}'"
            )))
        }
    };
    let base = StubLoadtestConfig {
        kind,
        num_sgs: args.get_u64("sgs", 2)? as usize,
        workers: args.get_u64("workers", 2)? as usize,
        duration_s: args.get_u64("duration", 15)?,
        time_scale: args.get_f64("time-scale", 1.0)?,
        util: args.get_f64("util", 0.8)?,
        dags_per_class: args.get_u64("dags-per-class", 1)? as usize,
        seed: args.get_u64("seed", 42)?,
        ..StubLoadtestConfig::default()
    };
    if base.time_scale <= 0.0 || !base.time_scale.is_finite() {
        return Err(CliError("--time-scale must be a positive number".into()));
    }
    if base.num_sgs == 0 || base.workers == 0 {
        return Err(CliError("--sgs and --workers must be at least 1".into()));
    }
    if base.util <= 0.0 || !base.util.is_finite() {
        return Err(CliError("--util must be a positive number".into()));
    }
    let mut rows = Vec::new();
    for policy in policies {
        let cfg = StubLoadtestConfig { policy, ..base.clone() };
        let (server, schedule) =
            loadgen::prepare_stub(&cfg).map_err(|e| CliError(e.to_string()))?;
        let label = loadgen::policy_label(policy);
        println!(
            "loadtest [{label}]: {} requests over {:.1}s wall ({:?}, {} SGS x {} workers, \
             util {:.0}%, time-scale {})",
            schedule.len(),
            schedule.last().map(|&(t, _)| t as f64 / 1e6).unwrap_or(0.0),
            kind,
            cfg.num_sgs,
            cfg.workers,
            cfg.util * 100.0,
            cfg.time_scale,
        );
        let report = loadgen::run(&server, &schedule, label, &LoadgenOptions::default());
        println!("{}", report.format());
        server.shutdown();
        rows.push(report.to_json());
    }
    if let Some(out) = args.get("out") {
        let doc = json::obj(vec![
            ("bench", Json::Str("loadtest".into())),
            ("workload", Json::Str(format!("{kind:?}").to_lowercase())),
            ("rows", Json::Arr(rows)),
        ]);
        std::fs::write(out, doc.to_pretty()).map_err(|e| CliError(e.to_string()))?;
        println!("report written to {out}");
    }
    Ok(())
}

fn cmd_validate(raw: &[String]) -> Result<(), CliError> {
    let cmd = Command::new("validate", "config + artifact + determinism self-check")
        .opt("config", "platform config JSON to validate");
    let args = cmd.parse(raw)?;
    let cfg = load_config(&args)?;
    cfg.validate().map_err(|e| CliError(e.to_string()))?;
    println!("config OK ({} total cores)", cfg.total_cores());
    // determinism check: two short identical sims must agree exactly
    let run = || {
        let apps = scaled_mix(WorkloadKind::W2, &cfg, 1, 1);
        let opts = SimOptions {
            seed: 1,
            horizon: 10 * SEC,
            warmup: 2 * SEC,
            ..SimOptions::default()
        };
        let mut p = SimPlatform::new(cfg.clone(), apps, opts);
        let row = p.run();
        (row.completed, row.p99, row.cold_starts)
    };
    if run() != run() {
        return Err(CliError("determinism check FAILED".into()));
    }
    println!("determinism OK");
    let dir = std::path::PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        let m = archipelago::runtime::Manifest::load(&dir)
            .map_err(|e| CliError(e.to_string()))?;
        println!("artifacts OK ({} entries)", m.entries.len());
    } else {
        println!("artifacts not built (run `make artifacts`) — skipped");
    }
    Ok(())
}
