//! End-to-end driver (the mandated E2E validation): serve *real* model
//! inference through the full three-layer stack.
//!
//! Layer 1/2 (build time): Pallas fused-MLP kernels inside JAX models,
//! AOT-lowered to `artifacts/*.hlo.txt` by `make artifacts`.
//! Layer 3 (this binary): the real-time Archipelago server — SRSF queue,
//! sandbox-aware dispatch, per-worker PJRT executable caches — serving
//! batched requests with Python nowhere on the request path.
//!
//! Reports warm/cold latency split and sustained throughput; the run is
//! recorded in EXPERIMENTS.md §E2E.
//!
//! ```sh
//! make artifacts && cargo run --release --example ml_serving
//! ```

use std::path::PathBuf;
use std::time::Instant;

use archipelago::config::SchedPolicy;
use archipelago::platform::realtime::Server;
use archipelago::util::stats::Summary;

fn artifacts_dir() -> PathBuf {
    std::env::var("ARCHIPELAGO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

/// Without artifacts (or the real `xla` crate) the same serving stack
/// still runs end-to-end: the stub executor stands in for PJRT, and the
/// shared coordinator schedules a single-function model plus a 3-stage
/// DAG exactly as it would the compiled artifacts.
fn stub_demo() {
    use archipelago::config::MS;
    use archipelago::dag::{DagId, DagSpec};
    use archipelago::platform::realtime::RtOptions;
    use archipelago::runtime::{Manifest, StubExecutorFactory};
    use std::sync::Arc;
    use std::time::Duration;

    let dags = vec![
        DagSpec::single(DagId(0), "score", 2 * MS, 50 * MS, 128, 200 * MS),
        DagSpec::chain(
            DagId(1),
            "pipeline",
            &[
                (2 * MS, 50 * MS, 128),
                (3 * MS, 50 * MS, 128),
                (2 * MS, 50 * MS, 128),
            ],
            400 * MS,
        ),
    ];
    let factory = Arc::new(StubExecutorFactory {
        setup_cost: Duration::from_millis(25),
        exec_cost: Duration::from_millis(2),
        ..Default::default()
    });
    let server = Server::start_with(
        factory,
        dags,
        RtOptions::default(),
        &["score"],
        Manifest::empty(),
    )
    .expect("stub server start");
    let pipeline = server.dag_id("pipeline").expect("registered");
    let c = server
        .submit("score", vec![0.5, 1.5], 200_000)
        .recv()
        .expect("completion");
    println!(
        "stub single-fn: warm={} e2e={}us output={:?}",
        !c.cold,
        c.e2e_us,
        c.outputs[0].as_f32().unwrap()
    );
    let d = server
        .submit_dag(pipeline, vec![1.0, 2.0], 400_000)
        .recv()
        .expect("dag completion");
    println!(
        "stub 3-stage DAG: stages={} colds={} e2e={}us met={}",
        d.functions.len(),
        d.cold_starts,
        d.e2e_us,
        d.deadline_met
    );
    println!("{}", server.summary().format_line("realtime (stub)"));
    server.shutdown();
    println!("\nOK: coordinator-driven serving ran end-to-end on the stub executor");
    println!("(run `make artifacts` + link the real `xla` crate for PJRT inference)");
}

fn main() {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no compiled artifacts found (looked in {dir:?})");
        eprintln!("running the stub-executor demo instead — same scheduling path, fake compute");
        stub_demo();
        return;
    }
    let workers = 2;
    println!("starting real-time server: {workers} workers, SRSF, prewarm=mlp_infer_b1/b4");
    let t0 = Instant::now();
    let server = match Server::start(
        &dir,
        workers,
        SchedPolicy::Srsf,
        &["mlp_infer_b1", "mlp_infer_b4"],
    ) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("server start failed: {e}");
            std::process::exit(2);
        }
    };
    println!(
        "  up in {:.2}s ({} artifacts in manifest)",
        t0.elapsed().as_secs_f64(),
        server.manifest.entries.len()
    );

    // ---- Phase 1: warm latency profile (the common case) ----
    let n_warm = 500;
    let input: Vec<f32> = (0..256).map(|i| (i as f32 * 0.017).sin()).collect();
    let mut warm_lat = Summary::new();
    let t0 = Instant::now();
    for i in 0..n_warm {
        let mut x = input.clone();
        x[0] = i as f32 * 0.001; // vary inputs
        let rx = server.submit("mlp_infer_b1", x, 100_000);
        let c = rx.recv().expect("completion");
        assert!(!c.cold, "prewarmed");
        // verify real inference output
        let probs = c.outputs[0].as_f32().expect("probs");
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "softmax row sum {s}");
        warm_lat.record(c.e2e_us as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\nwarm serving ({n_warm} sequential requests, batch=1):");
    println!(
        "  e2e latency  : p50={:.0}us p99={:.0}us max={:.0}us",
        warm_lat.quantile(0.5),
        warm_lat.quantile(0.99),
        warm_lat.max()
    );
    println!("  throughput   : {:.0} req/s", n_warm as f64 / wall);

    // ---- Phase 2: cold vs warm asymmetry (the paper's motivation) ----
    let cold_input: Vec<f32> = vec![0.1; 128];
    let rx = server.submit("anomaly_score_b1", cold_input.clone(), 500_000);
    let cold = rx.recv().expect("completion");
    assert!(cold.cold);
    let rx = server.submit("anomaly_score_b1", cold_input, 500_000);
    let warm = rx.recv().expect("completion");
    assert!(!warm.cold, "second hit reuses the warm worker");
    println!("\ncold-start asymmetry (anomaly_score_b1):");
    println!(
        "  cold: setup={}us exec={}us e2e={}us",
        cold.setup_us, cold.exec_us, cold.e2e_us
    );
    println!(
        "  warm: setup={}us exec={}us e2e={}us",
        warm.setup_us, warm.exec_us, warm.e2e_us
    );
    let sne = cold.setup_us as f64 / warm.exec_us.max(1) as f64;
    println!("  SNE (setup/exec) = {sne:.1}x — the paper's T3 in the flesh");

    // ---- Phase 3: concurrent batched load across all three models ----
    let n_conc = 300;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_conc)
        .map(|i| match i % 3 {
            0 => server.submit("mlp_infer_b4", vec![0.2; 4 * 256], 200_000),
            1 => server.submit("anomaly_score_b4", vec![0.3; 4 * 128], 400_000),
            _ => server.submit("mlp_infer_b1", vec![0.4; 256], 100_000),
        })
        .collect();
    let mut e2e = Summary::new();
    let mut colds = 0;
    for rx in rxs {
        let c = rx.recv().expect("completion");
        e2e.record(c.e2e_us as f64);
        colds += u32::from(c.cold);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\nmixed concurrent load ({n_conc} requests, 3 models, batch 1-4):");
    println!(
        "  e2e latency  : p50={:.0}us p99={:.0}us",
        e2e.quantile(0.5),
        e2e.quantile(0.99)
    );
    println!("  throughput   : {:.0} req/s", n_conc as f64 / wall);
    println!("  cold starts  : {colds} (first touch of anomaly_score_b4 per worker)");
    println!("  warm sets    : {:?}", server.warm_counts());

    server.shutdown();
    println!("\nOK: full three-layer stack served real inference end-to-end");
}
