//! Fault-tolerance demo (§6.1): worker fail-stop mid-run, then an SGS
//! fail-stop, with the platform adapting — queuing-delay-driven scale
//! out after worker loss, LBS re-routing after SGS loss — and the state
//! store round-tripping service state.
//!
//! ```sh
//! cargo run --release --example fault_tolerance
//! ```

use archipelago::config::{Config, SEC};
use archipelago::dag::{DagId, DagSpec};
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::sgs::SgsId;
use archipelago::state_store::StateStore;
use archipelago::util::json::{self, Json};
use archipelago::worker::WorkerId;
use archipelago::workload::{App, ArrivalProcess, DagClass};

fn mk_apps() -> Vec<App> {
    let dag = DagSpec::single(DagId(0), "svc", 50_000, 200_000, 128, 250_000);
    vec![App {
        class: DagClass::C1,
        dag,
        arrivals: ArrivalProcess::constant(100.0),
    }]
}

fn main() {
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 2;
    cfg.cluster.workers_per_sgs = 3;
    cfg.cluster.cores_per_worker = 4;
    cfg.cluster.proactive_pool_mb = 8 * 1024;

    // --- Scenario 1: worker failures ---
    let opts = SimOptions {
        seed: 11,
        horizon: 40 * SEC,
        warmup: 4 * SEC,
        ..SimOptions::default()
    };
    let mut p = SimPlatform::new(cfg.clone(), mk_apps(), opts.clone());
    // kill 2 of 3 workers in the home pool at t=10s; recover at t=25s
    p.inject_worker_failure(10 * SEC, SgsId(0), WorkerId(0));
    p.inject_worker_failure(10 * SEC, SgsId(0), WorkerId(1));
    p.inject_worker_recovery(25 * SEC, SgsId(0), WorkerId(0));
    p.inject_worker_recovery(25 * SEC, SgsId(0), WorkerId(1));
    p.inject_worker_failure(10 * SEC, SgsId(1), WorkerId(0));
    p.inject_worker_recovery(25 * SEC, SgsId(1), WorkerId(0));
    let row = p.run();
    println!("scenario 1: 3 worker fail-stops at t=10s, recovery at t=25s");
    println!("{}", row.format_line("  worker-failures"));
    println!(
        "  scale-outs triggered: {} (queuing delay is the §6.1 failure signal)",
        p.lbs().scale_outs()
    );
    assert!(row.completed > 2000, "platform kept serving");
    assert!(
        row.deadline_met_rate > 0.5,
        "degraded but alive: {}",
        row.deadline_met_rate
    );

    // --- Scenario 2: SGS fail-stop ---
    let mut p = SimPlatform::new(cfg.clone(), mk_apps(), opts);
    p.inject_sgs_failure(12 * SEC, SgsId(0));
    let row = p.run();
    println!("\nscenario 2: SGS 0 fail-stop at t=12s");
    println!("{}", row.format_line("  sgs-failure"));
    let active = p.lbs().active_sgs(DagId(0)).to_vec();
    println!("  active SGSs after failure: {active:?}");
    assert!(!active.contains(&SgsId(0)), "dead SGS evicted from routing");
    assert!(row.completed > 2000);

    // --- Scenario 3: state store recovery round-trip ---
    let store = StateStore::new();
    // services checkpoint their state (what §6.1 keeps "in a reliable
    // external store"): per-DAG SGS mapping + per-SGS sandbox counts
    store.put(
        "lbs/dag/0/active",
        Json::Arr(active.iter().map(|s| Json::Int(s.0 as i64)).collect()),
    );
    store.put(
        "sgs/1/estimates",
        json::obj(vec![("dag0.fn0", Json::Int(12))]),
    );
    let dir = std::env::temp_dir().join("archipelago_ft_example");
    let path = dir.join("checkpoint.json");
    store.save_to_file(&path).expect("checkpoint");
    let recovered = StateStore::load_from_file(&path).expect("recovery");
    assert_eq!(
        recovered.get("lbs/dag/0/active").unwrap().value,
        store.get("lbs/dag/0/active").unwrap().value
    );
    assert_eq!(
        recovered
            .get("sgs/1/estimates")
            .unwrap()
            .value
            .get("dag0.fn0")
            .unwrap()
            .as_i64(),
        Some(12)
    );
    std::fs::remove_dir_all(&dir).ok();
    println!("\nscenario 3: state store checkpoint/recovery round-trip OK");
    println!("\nOK: all three fault-tolerance scenarios passed");
}
