//! Quickstart: upload a DAG (the paper's JSON spec language), run a
//! small simulated cluster, and print the latency/deadline report.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use archipelago::config::{Config, SEC};
use archipelago::dag::{parse_dag_json, DagId};
use archipelago::metrics::fmt_us;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::workload::{App, ArrivalProcess, DagClass};

const DAG_SPEC: &str = r#"{
  "name": "thumbnail-pipeline",
  "deadline_us": 250000,
  "functions": [
    {"name": "classify", "exec_time_us": 40000, "setup_time_us": 200000,
     "mem_mb": 128, "artifact": "mlp_infer_b1"},
    {"name": "notify",   "exec_time_us": 10000, "setup_time_us": 125000,
     "mem_mb": 128}
  ],
  "edges": [[0, 1]]
}"#;

fn main() {
    // 1. Parse the user's DAG upload.
    let dag = parse_dag_json(DagId(0), DAG_SPEC).expect("valid spec");
    println!("uploaded DAG '{}':", dag.name);
    println!("  functions      : {}", dag.len());
    println!("  critical path  : {}", fmt_us(dag.total_cpl));
    println!("  deadline       : {}", fmt_us(dag.deadline));
    println!("  slack budget   : {}", fmt_us(dag.slack()));

    // 2. A small cluster: 2 SGSs × 4 workers × 4 cores.
    let mut cfg = Config::default();
    cfg.cluster.num_sgs = 2;
    cfg.cluster.workers_per_sgs = 4;
    cfg.cluster.cores_per_worker = 4;
    cfg.cluster.proactive_pool_mb = 8 * 1024;

    // 3. Offer 120 requests/second for 30 virtual seconds.
    let apps = vec![App {
        class: DagClass::C3,
        dag,
        arrivals: ArrivalProcess::constant(120.0),
    }];
    let opts = SimOptions {
        seed: 1,
        horizon: 30 * SEC,
        warmup: 3 * SEC,
        ..SimOptions::default()
    };
    let mut platform = SimPlatform::new(cfg, apps, opts);
    let row = platform.run();

    // 4. Report.
    println!("\nafter 30s simulated at 120 rps:");
    println!("{}", row.format_line("thumbnail-pipeline"));
    println!(
        "  queue delay    : p50={} p99={}",
        fmt_us(row.qdelay_p50),
        fmt_us(row.qdelay_p99),
    );
    println!(
        "  cold starts    : {} over {} requests ({:.2}%)",
        row.cold_starts,
        row.completed,
        100.0 * row.cold_starts as f64 / row.completed.max(1) as f64
    );
    println!(
        "  active SGSs    : {:?}",
        platform.lbs().active_sgs(DagId(0))
    );
    assert!(row.deadline_met_rate > 0.95, "quickstart should be healthy");
    println!("\nOK: >=95% of requests met the 250ms deadline");
}
