//! Multi-tenant macrobenchmark (a scaled-down §7.2): the C1–C4 class mix
//! on Archipelago vs the centralized-FIFO baseline, same workload, same
//! cluster size.
//!
//! ```sh
//! cargo run --release --example multi_tenant
//! ```

use archipelago::baseline::{BaselineKind, BaselineOptions, BaselineSim};
use archipelago::config::{Config, SEC};
use archipelago::metrics::fmt_us;
use archipelago::platform::{SimOptions, SimPlatform};
use archipelago::workload::{macro_mix, offered_cores, WorkloadKind};

fn main() {
    // The paper's testbed shape: 8 SGSs × 8 workers × 20 cores.
    let cfg = Config::default();
    let total_cores = cfg.total_cores() as f64;

    // Two DAGs per class at Table-1 rates keeps the cluster in the
    // paper's ~70–110% CPU band.
    let apps = macro_mix(WorkloadKind::W2, 2, 1.0, 7);
    println!(
        "workload: {} DAGs (C1-C4, sinusoidal), ~{:.0}% mean of {} cores",
        apps.len(),
        100.0 * apps.iter().map(offered_cores).sum::<f64>() / total_cores,
        total_cores
    );

    let horizon = 60 * SEC;
    let warmup = 10 * SEC;

    // --- Archipelago ---
    let opts = SimOptions {
        seed: 7,
        horizon,
        warmup,
        ..SimOptions::default()
    };
    let mut arch = SimPlatform::new(cfg.clone(), apps.clone(), opts);
    let arch_row = arch.run();

    // --- Baseline: centralized FIFO + reactive sandboxes ---
    let bopts = BaselineOptions {
        kind: BaselineKind::CentralizedFifo,
        seed: 7,
        horizon,
        warmup,
        ..BaselineOptions::default()
    };
    let mut base = BaselineSim::new(
        cfg.cluster.num_sgs * cfg.cluster.workers_per_sgs,
        cfg.cluster.cores_per_worker,
        cfg.cluster.proactive_pool_mb, // same container-memory budget as archipelago
        apps,
        bopts,
    );
    let base_row = base.run();

    println!("\n{}", arch_row.format_line("archipelago"));
    println!("{}", base_row.format_line("baseline (FIFO)"));
    println!("\nper-class deadline-met rates (archipelago, 2 DAGs each):");
    for (i, class) in ["C1", "C2", "C3", "C4"].iter().enumerate() {
        let ids = [2 * i as u32, 2 * i as u32 + 1];
        let (mut met, mut n, mut cold) = (0u64, 0u64, 0u64);
        for id in ids {
            if let Some(g) = arch.metrics().per_dag.get(&id) {
                met += g.deadlines_met;
                n += g.completed;
                cold += g.cold_starts;
            }
        }
        println!(
            "  {class}: met={:6.2}%  n={n}  cold={cold}",
            100.0 * met as f64 / n.max(1) as f64
        );
    }
    let tail_x = base_row.p999 as f64 / arch_row.p999.max(1) as f64;
    println!(
        "\ntail (p99.9) ratio baseline/archipelago: {tail_x:.1}x  \
         (paper: 20.8x W1, 36.0x W2)"
    );
    println!(
        "deadlines missed: archipelago {:.2}% vs baseline {:.2}% (paper: 0.98% vs 9.66%)",
        100.0 * (1.0 - arch_row.deadline_met_rate),
        100.0 * (1.0 - base_row.deadline_met_rate)
    );
    println!(
        "cold starts: archipelago {} vs baseline {} ({}x fewer)",
        arch_row.cold_starts,
        base_row.cold_starts,
        base_row.cold_starts / arch_row.cold_starts.max(1)
    );
    println!(
        "\nqueue delay p99.9: archipelago {} vs baseline {}",
        fmt_us(arch_row.qdelay_p999),
        fmt_us(base_row.qdelay_p999)
    );
    assert!(
        arch_row.deadline_met_rate > base_row.deadline_met_rate,
        "archipelago must beat the baseline"
    );
    println!("\nOK: archipelago dominates the baseline on this workload");
}
